"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_capacity, provision_performance,
                        provision_power)
from repro.core.systems import TiB
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter import ref as scan_ref

SYSTEMS = (TRADITIONAL, BIG_MEMORY, DIE_STACKED)

workloads = st.builds(
    Workload,
    db_size=st.floats(0.5 * TiB, 64 * TiB),
    percent_accessed=st.floats(0.01, 1.0),
)


# --------------------------------------------------------------------------
# analytical model invariants
# --------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(wl=workloads, sla=st.floats(1e-3, 5.0),
       sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_performance_provisioning_meets_sla_and_capacity(wl, sla, sys_i):
    d = provision_performance(SYSTEMS[sys_i], wl, sla)
    assert d.response_time <= sla * 1.001
    assert d.holds_workload


@settings(max_examples=60, deadline=None)
@given(wl=workloads, budget=st.floats(5e3, 5e6),
       sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_power_provisioning_respects_budget(wl, budget, sys_i):
    d = provision_power(SYSTEMS[sys_i], wl, budget)
    cap_power = provision_power(SYSTEMS[sys_i], wl, 0.0).power
    # budget below the capacity-floor cluster cost is infeasible by
    # construction (the workload must stay resident) — skip those
    if budget >= cap_power:
        assert d.power <= budget * 1.001
    assert d.holds_workload


@settings(max_examples=40, deadline=None)
@given(wl=workloads, sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_tighter_sla_never_needs_fewer_chips(wl, sys_i):
    tight = provision_performance(SYSTEMS[sys_i], wl, 0.01)
    loose = provision_performance(SYSTEMS[sys_i], wl, 1.0)
    assert tight.compute_chips >= loose.compute_chips
    assert tight.power >= loose.power * 0.999


@settings(max_examples=40, deadline=None)
@given(wl=workloads, sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_capacity_design_races_to_halt(wl, sys_i):
    """Capacity provisioning runs chips at the Eq.4/5 saturating point:
    adding cores can't help (bandwidth-bound) and removing them hurts."""
    d = provision_capacity(SYSTEMS[sys_i], wl)
    s = SYSTEMS[sys_i]
    assert d.chip_perf == min(s.chip_peak_perf, s.chip_bandwidth)
    assert d.holds_workload


@settings(max_examples=30, deadline=None)
@given(wl=workloads)
def test_bandwidth_capacity_ordering_is_invariant(wl):
    """The paper's Fig. 1 ordering holds for every workload: die-stacked
    always answers a fixed-fraction query fastest under capacity
    provisioning."""
    rts = {s.name: provision_capacity(s, wl).response_time for s in SYSTEMS}
    assert rts["die-stacked"] <= rts["traditional"] <= rts["big-memory"]


# --------------------------------------------------------------------------
# kernel invariants
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    codes=st.lists(st.integers(0, 127), min_size=1, max_size=2000),
    const=st.integers(0, 127),
    op=st.sampled_from(scan_ref.OPS),
)
def test_scan_filter_matches_numpy(codes, const, op):
    codes = np.asarray(codes, np.uint32)
    packed = scan_ref.pack(codes, 8)
    mask = scan_ops.scan_filter(packed, const, op, 8)
    got = np.asarray(scan_ref.unpack_mask(mask, 8))[:len(codes)]
    want = {
        "lt": codes < const, "le": codes <= const, "gt": codes > const,
        "ge": codes >= const, "eq": codes == const, "ne": codes != const,
    }[op]
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(codes=st.lists(st.integers(0, 32766), min_size=1, max_size=500),
       bits=st.sampled_from([4, 8, 16]))
def test_pack_unpack_roundtrip(codes, bits):
    vmax = (1 << (bits - 1)) - 1
    codes = np.asarray(codes, np.uint32) % (vmax + 1)
    packed = scan_ref.pack(codes, bits)
    got = np.asarray(scan_ref.unpack(packed, bits))[:len(codes)]
    np.testing.assert_array_equal(got, codes)


# --------------------------------------------------------------------------
# compressed store invariants (repro.store)
# --------------------------------------------------------------------------
from repro.store import (Encoding, EncodingStats, choose_encoding,
                         encode_chunk)

_bits_and_codes = st.sampled_from([4, 8, 16]).flatmap(
    lambda bits: st.tuples(
        st.just(bits),
        st.lists(st.integers(0, (1 << (bits - 1)) - 1),
                 min_size=0, max_size=1500)))


@settings(max_examples=40, deadline=None)
@given(bc=_bits_and_codes, enc=st.sampled_from([None, *Encoding]))
def test_encode_decode_roundtrip_every_encoding(bc, enc):
    """Exact round-trip for the selector's choice AND for each encoding
    forced — compression must never change a single code."""
    bits, codes = bc
    codes = np.asarray(codes, np.uint32)
    chunk = encode_chunk(codes, bits, enc)
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=40, deadline=None)
@given(bc=_bits_and_codes)
def test_roundtrip_sorted_runs(bc):
    """Sorted low-cardinality chunks (RLE's home turf) round-trip under
    whatever the selector picks."""
    bits, codes = bc
    codes = np.sort(np.asarray(codes, np.uint32) % 7)
    chunk = encode_chunk(codes, bits)
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([4, 8, 16]), n=st.integers(1, 2000),
       v=st.integers(0, 7))
def test_roundtrip_adversarial_single_run(bits, n, v):
    """One giant run — the degenerate best case for RLE."""
    codes = np.full(n, v, np.uint32)
    chunk = encode_chunk(codes, bits)
    assert chunk.encoding is Encoding.RLE
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([8, 16]), n=st.integers(2, 127))
def test_roundtrip_all_distinct(bits, n):
    """Every value distinct — the adversarial worst case for RLE; the
    selector must fall back to FOR or PLAIN, never expand."""
    codes = np.arange(n, dtype=np.uint32)
    chunk = encode_chunk(codes, bits)
    assert chunk.nbytes <= chunk.stats.plain_nbytes
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=60, deadline=None)
@given(bc=_bits_and_codes)
def test_choose_encoding_never_larger_than_plain(bc):
    """The selector's guarantee: the chosen physical footprint never
    exceeds today's plain packed format."""
    bits, codes = bc
    codes = np.asarray(codes, np.uint32)
    stats = EncodingStats.from_codes(codes, bits)
    chosen = choose_encoding(stats)
    assert stats.nbytes(chosen) <= stats.plain_nbytes
    chunk = encode_chunk(codes, bits)
    assert chunk.nbytes <= stats.plain_nbytes


# --------------------------------------------------------------------------
# resilience invariants (repro.resilience)
# --------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), stall=st.floats(0.0, 0.6),
       corrupt=st.floats(0.0, 0.3), timeout_mult=st.floats(0.5, 10.0),
       cap_factor=st.floats(0.3, 3.0))
def test_chaos_never_breaks_powercap_or_double_charges(
        seed, stall, corrupt, timeout_mult, cap_factor):
    """Under any seeded fault stream (stalls + chunk corruption) and any
    retry policy, (a) no sliding watt window ever exceeds the PowerCap
    budget — recovery extras are throttled like all other joules — and
    (b) the energy ledger holds exactly the placement engine's byte
    totals with at most one kind="recovery" line per query: retries
    never double-charge."""
    from collections import Counter

    from repro.db import Table
    from repro.energy.caps import PowerCap
    from repro.query import Pred, Query, QueryEngine
    from repro.resilience import ChaosHarness, ChunkGuard, FaultSpec, \
        RetryPolicy
    from repro.serve.sla import VirtualClock
    from repro.store import EncodedTable
    from repro.tier.placement import PlacementEngine, Policy
    from repro.tier.tiers import paper_tiers

    table = Table.synthetic("p", 2001, {"a": 8, "b": 8}, seed=2)
    query = Query(Pred("a", "lt", 60), aggregates=("b",))

    def build(power_cap=None, chaos=None):
        pe = PlacementEngine.for_table(
            table if chaos is None else chaos.guard.table,
            paper_tiers(max(1, table.nbytes // 2)), Policy.CACHE,
            chunk_rows=512)
        clock = VirtualClock()
        eng = QueryEngine(chaos.guard.table if chaos else table,
                          clock=clock, tiered=pe,
                          power_cap=power_cap, chaos=chaos)
        return eng, pe, clock

    # probe run sizes the watt budget relative to this workload's natural
    # power, so cap_factor < 1 genuinely forces throttling
    eng0, pe0, clk0 = build()
    for _ in range(3):
        eng0.submit(query)
        eng0.run()
    natural_w = pe0.meter.total_j / eng0.seconds_total
    cap = PowerCap(cap_factor * natural_w, eng0.seconds_total / 3)

    encoded = EncodedTable.from_table(table, chunk_rows=512)
    clean_s = pe0.tiers.service_s(512, 0, 1)
    chaos = ChaosHarness(
        FaultSpec(seed=seed, stall_rate=stall, corrupt_rate=corrupt),
        retry=RetryPolicy(timeout_s=timeout_mult * clean_s,
                          backoff_s=0.5 * clean_s, max_retries=2),
        guard=ChunkGuard(encoded))
    if corrupt > 0:
        chaos.inject_corruption()
    eng, pe, clock = build(power_cap=cap, chaos=chaos)
    for _ in range(6):
        eng.submit(query, deadline=clock() + 1e6)
        for r in eng.run():
            assert not r.degraded        # recovery on: repaired, not failed

    assert cap.report(now=clock())["budget_utilization"] <= 1 + 1e-9
    meter = pe.meter
    total_bytes = sum(c.fast_bytes + c.capacity_bytes
                      for c in meter.charges)
    assert total_bytes == pe.fast_bytes_total + pe.capacity_bytes_total
    recovery = [c for c in meter.charges if c.kind == "recovery"]
    assert all(n <= 1 for n in Counter(c.qid for c in recovery).values())
    assert pe.recovery_bytes_total == sum(
        c.fast_bytes + c.capacity_bytes for c in recovery)
    assert meter.recovery_j == sum(c.total_j for c in recovery)


# --------------------------------------------------------------------------
# MoE dispatch invariants
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(4, 64),
       e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_moe_dispatch_conservation(seed, s, e, k):
    """Every kept slot routes a real token to the expert its router chose,
    ranks are unique per expert, and combine weights of kept slots sum to
    <= 1 per token."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import _dispatch_indices

    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (s, e))
    w, idx = jax.lax.top_k(jax.nn.softmax(logits), k)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    cap = max(1, (s * k) // e)
    token_for, weight_for = _dispatch_indices(idx, w, e, cap)
    token_for = np.asarray(token_for)
    weight_for = np.asarray(weight_for)
    idx_np = np.asarray(idx)

    per_token = np.zeros(s)
    for ei in range(e):
        for ci in range(cap):
            wgt = weight_for[ei, ci]
            if wgt > 0:
                tok = token_for[ei, ci]
                assert ei in idx_np[tok], "token routed to unchosen expert"
                per_token[tok] += wgt
    assert (per_token <= 1.0 + 1e-5).all()


# --------------------------------------------------------------------------
# batched multi-chunk execution invariants (repro.store.exec batched=True)
# --------------------------------------------------------------------------
from repro.db.columnar import BitPackedColumn, Table
from repro.query.plan import And, Or, Pred
from repro.store import EncodedTable
from repro.store.exec import execute_encoded


def _random_store(seed: int, n_chunks: int, chunk_rows: int = 64):
    """A mixed-encoding table whose chunking has a ragged tail: sorted
    low-cardinality (RLE), clustered narrow (FOR), uniform (plain), and a
    wide 16-bit clustered column — every batched width-unification group
    in one table."""
    rng = np.random.default_rng(seed)
    n = int(n_chunks * chunk_rows - rng.integers(0, chunk_rows))
    n = max(n, 1)
    raw = {"r": np.sort(rng.integers(0, 6, n)),
           "f": 40 + rng.integers(0, 8, n),
           "u": rng.integers(0, 128, n),
           "w": 9000 + rng.integers(0, 100, n)}
    bits = {"r": 8, "f": 8, "u": 8, "w": 16}
    t = Table("p")
    for name, v in raw.items():
        t.add(BitPackedColumn.from_values(name, v, bits[name]))
    return raw, bits, EncodedTable.from_table(t, chunk_rows=chunk_rows)


_NP_OPS = {"lt": np.less, "le": np.less_equal, "gt": np.greater,
           "ge": np.greater_equal, "eq": np.equal, "ne": np.not_equal}


def _np_mask(plan, cols):
    if isinstance(plan, Pred):
        return _NP_OPS[plan.op](cols[plan.column], plan.constant)
    masks = [_np_mask(c, cols) for c in plan.children]
    out = masks[0]
    for m in masks[1:]:
        out = (out & m) if isinstance(plan, And) else (out | m)
    return out


def _np_aggs(plan, aggregates, raw, bits):
    cols = {n: np.asarray(v, np.int64) for n, v in raw.items()}
    sel = _np_mask(plan, cols)
    out = {}
    for a in aggregates:
        v = cols[a][sel]
        vmax = (1 << (bits[a] - 1)) - 1
        out[a] = ({"sum": int(v.sum()), "count": int(v.size),
                   "min": int(v.min()), "max": int(v.max())} if v.size
                  else {"sum": 0, "count": 0, "min": vmax, "max": 0})
    return out


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_chunks=st.integers(1, 17),
       shape=st.integers(0, 4))
def test_batched_exec_bit_exact_vs_per_chunk_and_numpy(seed, n_chunks,
                                                       shape):
    """One batched launch per (column-group, encoding) must be
    bit-identical to the per-chunk loop AND to a numpy oracle over the
    raw values — for every plan shape (fused RLE single-pred,
    cross-column, conjunction, disjunction, empty selection), any chunk
    count 1..17 with a ragged tail, on both kernel backends."""
    rng = np.random.default_rng(seed)
    raw, bits, enc = _random_store(seed, n_chunks)
    plan, aggs = [
        (Pred("r", "lt", 3), ("r",)),                 # fused RLE path
        (Pred("f", "ge", int(rng.integers(40, 48))), ("u", "w")),
        (And((Pred("u", "lt", 90), Pred("w", "ge", 9020))), ("f",)),
        (Or((Pred("r", "eq", 2), Pred("f", "gt", 44))), ("w", "r")),
        (Pred("u", "gt", 127), ("u",)),               # empty selection
    ][shape]
    want = _np_aggs(plan, aggs, raw, bits)
    for mode in ("xla_ref", "pallas"):
        batched = execute_encoded(plan, aggs, enc, mode=mode, batched=True)
        loop = execute_encoded(plan, aggs, enc, mode=mode, batched=False)
        assert batched == loop == want, (mode, plan)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_chunks=st.integers(1, 6))
def test_batched_all_chunks_quarantined_degrades_identically(seed,
                                                             n_chunks):
    """With every chunk of a column corrupted: repair-on guard feeds both
    paths repaired bytes (answers exact), repair-off raises the same
    typed error from both — the batched path never aggregates corrupt
    payloads and never diverges from the per-chunk loop."""
    from repro.resilience.recover import ChunkCorruptionError, ChunkGuard

    raw, bits, enc = _random_store(seed, n_chunks)
    guard = ChunkGuard(enc)
    col = enc.columns["u"]
    rng = np.random.default_rng(seed)
    for ch in col.chunks:                 # corrupt every chunk's payload
        if ch.words.size:
            w = np.asarray(ch.words).copy()
            w[rng.integers(w.size)] ^= np.uint32(1 << rng.integers(8))
            ch.words = w
    plan, aggs = Pred("u", "lt", 100), ("u",)
    want = _np_aggs(plan, aggs, raw, bits)

    guard.repair = True
    got_b = execute_encoded(plan, aggs, enc, mode="xla_ref", guard=guard,
                            batched=True)
    assert got_b == want
    assert len(guard.repaired) >= sum(ch.n_rows > 0 for ch in col.chunks)

    # re-corrupt, repair off: both paths die typed, neither answers
    _, _, enc2 = _random_store(seed, n_chunks)
    guard2 = ChunkGuard(enc2)
    guard2.repair = False
    col2 = enc2.columns["u"]
    rng = np.random.default_rng(seed)
    for ch in col2.chunks:
        if ch.words.size:
            w = np.asarray(ch.words).copy()
            w[rng.integers(w.size)] ^= np.uint32(1 << rng.integers(8))
            ch.words = w
    for batched in (True, False):
        with pytest.raises(ChunkCorruptionError):
            execute_encoded(plan, aggs, enc2, mode="xla_ref",
                            guard=guard2, batched=batched)


# --------------------------------------------------------------------------
# async prefetch invariants (repro.tier.prefetch)
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       policy_i=st.integers(0, 2),
       buf_frac=st.floats(0.02, 0.4),
       stall=st.floats(0.0, 0.5))
def test_prefetch_never_worse_never_wrong_never_double_charged(
        seed, policy_i, buf_frac, stall):
    """Under any policy, staging budget, and seeded stream-stall rate:
    (a) answers are bit-identical with and without the pipeline, (b) a
    fault-free pipelined replay is never slower than sync, (c) prefetch
    bytes live only on kind="prefetch"/"recovery" lines — demand totals
    (and so hit_rate) are untouched — and (d) the staging reservation
    never exceeds the fast tier."""
    from repro.db import Table as DbTable
    from repro.resilience import ChaosHarness, FaultSpec, RetryPolicy
    from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                            replay_trace)

    policy = list(Policy)[policy_i]
    tbl = DbTable.synthetic("t", 2048,
                            {f"c{i:02d}": 8 for i in range(8)}, seed=seed)
    tiers = paper_tiers(tbl.nbytes * 0.3, fast_gbps=10.0)
    trace = make_trace(tbl, TraceSpec(n_queries=30, seed=seed))
    buf = max(1, int(tiers.fast.capacity * buf_frac))

    def run(pf_bytes, chaos=None):
        return replay_trace(tbl, trace, tiers, policy, chunk_rows=256,
                            chaos=chaos, prefetch_bytes=pf_bytes)

    pe0, eng0, _ = run(0)
    pe1, eng1, _ = run(buf)
    for r0, r1 in zip(eng0.results, eng1.results):
        assert r0.aggregates == r1.aggregates
    assert eng1.seconds_total <= eng0.seconds_total + 1e-12
    assert pe1.prefetch_reserved_bytes <= tiers.fast.capacity
    # demand (hit-rate) totals exclude prefetch traffic entirely
    assert (pe1.fast_bytes_total + pe1.capacity_bytes_total
            == pe0.fast_bytes_total + pe0.capacity_bytes_total)
    pf_lines = [c for c in pe1.meter.charges if c.kind == "prefetch"]
    assert pe1.prefetch_streamed_bytes_total == sum(
        c.fast_bytes for c in pf_lines)
    assert pe1.prefetch_wasted_bytes_total == sum(
        c.capacity_bytes for c in pf_lines)
    assert pe1.meter.prefetch_j == sum(c.total_j for c in pf_lines)

    if stall > 0:
        from collections import Counter
        chaos = ChaosHarness(FaultSpec(seed=seed, stall_rate=stall),
                             retry=RetryPolicy(timeout_s=1e-6,
                                               max_retries=1))
        pe2, eng2, _ = run(buf, chaos=chaos)
        for r0, r2 in zip(eng0.results, eng2.results):
            assert r0.aggregates == r2.aggregates    # stalls never wrong
        recovery = [c for c in pe2.meter.charges if c.kind == "recovery"]
        assert all(n <= 1 for n in
                   Counter(c.qid for c in recovery).values())
        # stalled-stream waste is on the recovery/prefetch ledgers only
        assert pe2.recovery_bytes_total == sum(
            c.fast_bytes + c.capacity_bytes for c in recovery)


# --------------------------------------------------------------------------
# grouped aggregation & hash join invariants (repro.query.relational)
# --------------------------------------------------------------------------
from repro.query import GroupBy, HashJoin, relational
from repro.store.exec import execute_grouped_encoded


def _np_grouped_truth(raw, key, aggs, sel):
    """Independent grouped ground truth straight off the raw values —
    shares no code with the paths under test."""
    cols = {n: np.asarray(v, np.int64) for n, v in raw.items()}
    groups = {}
    for kv in np.unique(cols[key][sel]):
        m = sel & (cols[key] == kv)
        groups[int(kv)] = {
            "count": int(m.sum()),
            "sums": {a: int(cols[a][m].sum()) for a in sorted(aggs)}}
    return {"groups": groups, "count": int(sel.sum())}


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_chunks=st.integers(1, 17),
       shape=st.integers(0, 4))
def test_grouped_bit_exact_across_every_path(seed, n_chunks, shape):
    """GroupBy/HashJoin over random mixed-encoding tables (1..17 chunks,
    ragged tail): the plain-table kernel path, the compressed store (all
    three strategies), and the sharded table agree bit-exactly with an
    independent numpy truth under PALLAS and XLA_REF — including empty
    selections, joins, and the 16-bit key fallback."""
    import jax

    from repro.launch.mesh import make_mesh
    from repro.query.sharded import ShardedTable
    from repro.store.sharded import ShardedEncodedTable

    rng = np.random.default_rng(seed)
    raw, bits, enc = _random_store(seed, n_chunks)
    t = Table("p")
    for name, v in raw.items():
        t.add(BitPackedColumn.from_values(name, v, bits[name]))
    dim = Table("d")
    dim.add(BitPackedColumn.from_values(
        "u", rng.choice(128, size=5, replace=False), 8))
    cols = {n: np.asarray(v, np.int64) for n, v in raw.items()}
    query, sel = [
        (GroupBy("r", ("u", "w"), where=Pred("f", "ge", 44)),
         cols["f"] >= 44),
        (GroupBy("f"), np.ones(len(cols["f"]), bool)),  # count-only dense
        (GroupBy("r", where=Pred("r", "lt", 3)),        # RLE-fused shape
         cols["r"] < 3),
        (HashJoin(dim, "u", "u", aggs=("f",)),          # join clip
         np.isin(cols["u"], dim.columns["u"].decode())),
        (GroupBy("u", ("r",), where=Pred("u", "gt", 127)),  # empty sel
         np.zeros(len(cols["u"]), bool)),
    ][shape]
    if isinstance(query, HashJoin):
        sel = sel & np.ones(len(cols["u"]), bool)
    want = _np_grouped_truth(raw, query.key, query.aggs, sel)
    assert relational.execute_grouped_oracle(query, t) == want
    mesh = make_mesh((jax.device_count(),), ("data",))
    st = ShardedTable.shard(t, mesh)
    se = ShardedEncodedTable.shard(enc, mesh)
    for mode in ("pallas", "xla_ref"):
        assert relational.execute_grouped(query, t, mode=mode) == want
        assert execute_grouped_encoded(query, enc, mode=mode) == want
        assert st.execute_grouped(query, mode=mode) == want
        assert se.execute_grouped(query, mode=mode) == want


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_chunks=st.integers(1, 5))
def test_grouped_all_chunks_quarantined_repairs_or_dies_typed(seed,
                                                              n_chunks):
    """Every chunk of the group key corrupted: with repair on, the
    grouped result is still exact (corrupt payloads never aggregate);
    with repair off, the query dies with the typed corruption error."""
    from repro.resilience.recover import ChunkCorruptionError, ChunkGuard

    def corrupt_all(store, rng):
        """Flip one payload bit in every chunk of the key column — RLE
        chunks carry run planes (values/lengths), the rest packed words
        (the same split faults.FaultInjector.flip_bit makes)."""
        hit = 0
        for ch in store.columns["r"].chunks:
            if ch.values is not None and ch.values.size:
                v = np.asarray(ch.values).copy()
                v[rng.integers(v.size)] ^= np.int32(1 << rng.integers(8))
                ch.values = v
                hit += 1
            elif ch.words is not None and ch.words.size:
                w = np.asarray(ch.words).copy()
                w[rng.integers(w.size)] ^= np.uint32(1 << rng.integers(8))
                ch.words = w
                hit += 1
        return hit

    raw, bits, enc = _random_store(seed, n_chunks)
    guard = ChunkGuard(enc)
    n_bad = corrupt_all(enc, np.random.default_rng(seed))
    q = GroupBy("r", ("u",))
    want = _np_grouped_truth(raw, "r", ("u",), np.ones(len(raw["r"]), bool))
    guard.repair = True
    got = execute_grouped_encoded(q, enc, mode="xla_ref", guard=guard)
    assert got == want
    assert len(guard.repaired) >= n_bad

    _, _, enc2 = _random_store(seed, n_chunks)
    guard2 = ChunkGuard(enc2)
    guard2.repair = False
    corrupt_all(enc2, np.random.default_rng(seed))
    with pytest.raises(ChunkCorruptionError):
        execute_grouped_encoded(q, enc2, mode="xla_ref", guard=guard2)
