"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_capacity, provision_performance,
                        provision_power)
from repro.core.systems import TiB
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter import ref as scan_ref

SYSTEMS = (TRADITIONAL, BIG_MEMORY, DIE_STACKED)

workloads = st.builds(
    Workload,
    db_size=st.floats(0.5 * TiB, 64 * TiB),
    percent_accessed=st.floats(0.01, 1.0),
)


# --------------------------------------------------------------------------
# analytical model invariants
# --------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(wl=workloads, sla=st.floats(1e-3, 5.0),
       sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_performance_provisioning_meets_sla_and_capacity(wl, sla, sys_i):
    d = provision_performance(SYSTEMS[sys_i], wl, sla)
    assert d.response_time <= sla * 1.001
    assert d.holds_workload


@settings(max_examples=60, deadline=None)
@given(wl=workloads, budget=st.floats(5e3, 5e6),
       sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_power_provisioning_respects_budget(wl, budget, sys_i):
    d = provision_power(SYSTEMS[sys_i], wl, budget)
    cap_power = provision_power(SYSTEMS[sys_i], wl, 0.0).power
    # budget below the capacity-floor cluster cost is infeasible by
    # construction (the workload must stay resident) — skip those
    if budget >= cap_power:
        assert d.power <= budget * 1.001
    assert d.holds_workload


@settings(max_examples=40, deadline=None)
@given(wl=workloads, sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_tighter_sla_never_needs_fewer_chips(wl, sys_i):
    tight = provision_performance(SYSTEMS[sys_i], wl, 0.01)
    loose = provision_performance(SYSTEMS[sys_i], wl, 1.0)
    assert tight.compute_chips >= loose.compute_chips
    assert tight.power >= loose.power * 0.999


@settings(max_examples=40, deadline=None)
@given(wl=workloads, sys_i=st.integers(0, len(SYSTEMS) - 1))
def test_capacity_design_races_to_halt(wl, sys_i):
    """Capacity provisioning runs chips at the Eq.4/5 saturating point:
    adding cores can't help (bandwidth-bound) and removing them hurts."""
    d = provision_capacity(SYSTEMS[sys_i], wl)
    s = SYSTEMS[sys_i]
    assert d.chip_perf == min(s.chip_peak_perf, s.chip_bandwidth)
    assert d.holds_workload


@settings(max_examples=30, deadline=None)
@given(wl=workloads)
def test_bandwidth_capacity_ordering_is_invariant(wl):
    """The paper's Fig. 1 ordering holds for every workload: die-stacked
    always answers a fixed-fraction query fastest under capacity
    provisioning."""
    rts = {s.name: provision_capacity(s, wl).response_time for s in SYSTEMS}
    assert rts["die-stacked"] <= rts["traditional"] <= rts["big-memory"]


# --------------------------------------------------------------------------
# kernel invariants
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    codes=st.lists(st.integers(0, 127), min_size=1, max_size=2000),
    const=st.integers(0, 127),
    op=st.sampled_from(scan_ref.OPS),
)
def test_scan_filter_matches_numpy(codes, const, op):
    codes = np.asarray(codes, np.uint32)
    packed = scan_ref.pack(codes, 8)
    mask = scan_ops.scan_filter(packed, const, op, 8)
    got = np.asarray(scan_ref.unpack_mask(mask, 8))[:len(codes)]
    want = {
        "lt": codes < const, "le": codes <= const, "gt": codes > const,
        "ge": codes >= const, "eq": codes == const, "ne": codes != const,
    }[op]
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(codes=st.lists(st.integers(0, 32766), min_size=1, max_size=500),
       bits=st.sampled_from([4, 8, 16]))
def test_pack_unpack_roundtrip(codes, bits):
    vmax = (1 << (bits - 1)) - 1
    codes = np.asarray(codes, np.uint32) % (vmax + 1)
    packed = scan_ref.pack(codes, bits)
    got = np.asarray(scan_ref.unpack(packed, bits))[:len(codes)]
    np.testing.assert_array_equal(got, codes)


# --------------------------------------------------------------------------
# compressed store invariants (repro.store)
# --------------------------------------------------------------------------
from repro.store import (Encoding, EncodingStats, choose_encoding,
                         encode_chunk)

_bits_and_codes = st.sampled_from([4, 8, 16]).flatmap(
    lambda bits: st.tuples(
        st.just(bits),
        st.lists(st.integers(0, (1 << (bits - 1)) - 1),
                 min_size=0, max_size=1500)))


@settings(max_examples=40, deadline=None)
@given(bc=_bits_and_codes, enc=st.sampled_from([None, *Encoding]))
def test_encode_decode_roundtrip_every_encoding(bc, enc):
    """Exact round-trip for the selector's choice AND for each encoding
    forced — compression must never change a single code."""
    bits, codes = bc
    codes = np.asarray(codes, np.uint32)
    chunk = encode_chunk(codes, bits, enc)
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=40, deadline=None)
@given(bc=_bits_and_codes)
def test_roundtrip_sorted_runs(bc):
    """Sorted low-cardinality chunks (RLE's home turf) round-trip under
    whatever the selector picks."""
    bits, codes = bc
    codes = np.sort(np.asarray(codes, np.uint32) % 7)
    chunk = encode_chunk(codes, bits)
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([4, 8, 16]), n=st.integers(1, 2000),
       v=st.integers(0, 7))
def test_roundtrip_adversarial_single_run(bits, n, v):
    """One giant run — the degenerate best case for RLE."""
    codes = np.full(n, v, np.uint32)
    chunk = encode_chunk(codes, bits)
    assert chunk.encoding is Encoding.RLE
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([8, 16]), n=st.integers(2, 127))
def test_roundtrip_all_distinct(bits, n):
    """Every value distinct — the adversarial worst case for RLE; the
    selector must fall back to FOR or PLAIN, never expand."""
    codes = np.arange(n, dtype=np.uint32)
    chunk = encode_chunk(codes, bits)
    assert chunk.nbytes <= chunk.stats.plain_nbytes
    np.testing.assert_array_equal(chunk.decode(), codes)


@settings(max_examples=60, deadline=None)
@given(bc=_bits_and_codes)
def test_choose_encoding_never_larger_than_plain(bc):
    """The selector's guarantee: the chosen physical footprint never
    exceeds today's plain packed format."""
    bits, codes = bc
    codes = np.asarray(codes, np.uint32)
    stats = EncodingStats.from_codes(codes, bits)
    chosen = choose_encoding(stats)
    assert stats.nbytes(chosen) <= stats.plain_nbytes
    chunk = encode_chunk(codes, bits)
    assert chunk.nbytes <= stats.plain_nbytes


# --------------------------------------------------------------------------
# resilience invariants (repro.resilience)
# --------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), stall=st.floats(0.0, 0.6),
       corrupt=st.floats(0.0, 0.3), timeout_mult=st.floats(0.5, 10.0),
       cap_factor=st.floats(0.3, 3.0))
def test_chaos_never_breaks_powercap_or_double_charges(
        seed, stall, corrupt, timeout_mult, cap_factor):
    """Under any seeded fault stream (stalls + chunk corruption) and any
    retry policy, (a) no sliding watt window ever exceeds the PowerCap
    budget — recovery extras are throttled like all other joules — and
    (b) the energy ledger holds exactly the placement engine's byte
    totals with at most one kind="recovery" line per query: retries
    never double-charge."""
    from collections import Counter

    from repro.db import Table
    from repro.energy.caps import PowerCap
    from repro.query import Pred, Query, QueryEngine
    from repro.resilience import ChaosHarness, ChunkGuard, FaultSpec, \
        RetryPolicy
    from repro.serve.sla import VirtualClock
    from repro.store import EncodedTable
    from repro.tier.placement import PlacementEngine, Policy
    from repro.tier.tiers import paper_tiers

    table = Table.synthetic("p", 2001, {"a": 8, "b": 8}, seed=2)
    query = Query(Pred("a", "lt", 60), aggregates=("b",))

    def build(power_cap=None, chaos=None):
        pe = PlacementEngine.for_table(
            table if chaos is None else chaos.guard.table,
            paper_tiers(max(1, table.nbytes // 2)), Policy.CACHE,
            chunk_rows=512)
        clock = VirtualClock()
        eng = QueryEngine(chaos.guard.table if chaos else table,
                          clock=clock, tiered=pe,
                          power_cap=power_cap, chaos=chaos)
        return eng, pe, clock

    # probe run sizes the watt budget relative to this workload's natural
    # power, so cap_factor < 1 genuinely forces throttling
    eng0, pe0, clk0 = build()
    for _ in range(3):
        eng0.submit(query)
        eng0.run()
    natural_w = pe0.meter.total_j / eng0.seconds_total
    cap = PowerCap(cap_factor * natural_w, eng0.seconds_total / 3)

    encoded = EncodedTable.from_table(table, chunk_rows=512)
    clean_s = pe0.tiers.service_s(512, 0, 1)
    chaos = ChaosHarness(
        FaultSpec(seed=seed, stall_rate=stall, corrupt_rate=corrupt),
        retry=RetryPolicy(timeout_s=timeout_mult * clean_s,
                          backoff_s=0.5 * clean_s, max_retries=2),
        guard=ChunkGuard(encoded))
    if corrupt > 0:
        chaos.inject_corruption()
    eng, pe, clock = build(power_cap=cap, chaos=chaos)
    for _ in range(6):
        eng.submit(query, deadline=clock() + 1e6)
        for r in eng.run():
            assert not r.degraded        # recovery on: repaired, not failed

    assert cap.report(now=clock())["budget_utilization"] <= 1 + 1e-9
    meter = pe.meter
    total_bytes = sum(c.fast_bytes + c.capacity_bytes
                      for c in meter.charges)
    assert total_bytes == pe.fast_bytes_total + pe.capacity_bytes_total
    recovery = [c for c in meter.charges if c.kind == "recovery"]
    assert all(n <= 1 for n in Counter(c.qid for c in recovery).values())
    assert pe.recovery_bytes_total == sum(
        c.fast_bytes + c.capacity_bytes for c in recovery)
    assert meter.recovery_j == sum(c.total_j for c in recovery)


# --------------------------------------------------------------------------
# MoE dispatch invariants
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(4, 64),
       e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_moe_dispatch_conservation(seed, s, e, k):
    """Every kept slot routes a real token to the expert its router chose,
    ranks are unique per expert, and combine weights of kept slots sum to
    <= 1 per token."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import _dispatch_indices

    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (s, e))
    w, idx = jax.lax.top_k(jax.nn.softmax(logits), k)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    cap = max(1, (s * k) // e)
    token_for, weight_for = _dispatch_indices(idx, w, e, cap)
    token_for = np.asarray(token_for)
    weight_for = np.asarray(weight_for)
    idx_np = np.asarray(idx)

    per_token = np.zeros(s)
    for ei in range(e):
        for ci in range(cap):
            wgt = weight_for[ei, ci]
            if wgt > 0:
                tok = token_for[ei, ci]
                assert ei in idx_np[tok], "token routed to unchosen expert"
                per_token[tok] += wgt
    assert (per_token <= 1.0 + 1e-5).all()
