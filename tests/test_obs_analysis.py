"""Observability analysis: critical paths, SLO burn rates, trace diffs.

Pins down PR 10's contracts:

- `critical_path`/`verify` reconcile exactly on every execution path —
  plain tiered, encoded, sharded, grouped, prefetch overlap, chaos —
  and flag tampered span trees instead of mis-attributing them;
- same-seed chaos replays emit **byte-identical** SLO alert streams;
  burn-rate rules fire on sustained error burns and resolve when the
  short window goes quiet, at computed (never accumulated) timestamps;
- `RingSeries` ring-buffer semantics, `latency_percentile` and
  `Histogram` edge cases (empty / single / all-equal);
- the Chrome trace export matches its golden waterfall, serializes with
  sorted keys, and keeps X events ts-monotone per (pid, tid) lane;
- `diff_digests` names the dominant regressing span category, and
  `check_regress.py` prints it when the gate trips;
- `whatif_fast_fraction` stays consistent with the advise_tier_split
  decision surface.
"""
import json
import os
import sys
from pathlib import Path
from types import SimpleNamespace

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.advisor import whatif_fast_fraction
from repro.db import Table
from repro.launch.mesh import make_mesh
from repro.obs import (ConservationError, RingSeries, SLOMonitor, Tracer,
                       attribute, chrome_trace_json, critical_path,
                       diff_digests, diff_traces, digest, verify)
from repro.obs.critical_path import CATEGORIES
from repro.obs.export import waterfall_query
from repro.obs.metrics import Histogram
from repro.obs.slo import BurnRateRule, default_rules
from repro.query import Query, QueryEngine, ShardedTable
from repro.query.plan import GroupBy, Pred
from repro.resilience import (ChaosHarness, ChunkGuard, FaultSpec,
                              RetryPolicy)
from repro.serve.sla import VirtualClock, latency_percentile
from repro.store import EncodedTable
from repro.tier import (PlacementEngine, Policy, TraceSpec, make_trace,
                        paper_tiers, replay_trace, zipf_hit_curve)
from repro.tier.prefetch import PrefetchPipeline

N_ROWS, CHUNK_ROWS = 4096, 512
GOLDEN = Path(__file__).parent / "golden"


def make_table(seed=1, n_cols=8):
    return Table.synthetic("obs", N_ROWS,
                           {f"c{i:02d}": 8 for i in range(n_cols)},
                           seed=seed)


def tiered_engine(table, *, policy=Policy.CACHE, fast_frac=0.5, **kw):
    from repro.energy.meter import EnergyMeter
    tiers = paper_tiers(table.nbytes * fast_frac, fast_gbps=10.0)
    pe = PlacementEngine.for_table(table, tiers, policy,
                                   chunk_rows=CHUNK_ROWS,
                                   meter=EnergyMeter(tiers))
    tracer = Tracer()
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock(), tracer=tracer, **kw)
    return eng, pe, tracer


def run_queries(eng, n=4):
    for _ in range(n):
        q = Query(Pred("c00", "ge", 10), aggregates=("c01",))
        assert eng.submit(q, deadline=eng.clock() + 100.0) is not None
        eng.run()


def monitored_chaos_run(n_queries=40):
    """Seeded fault replay with monitor + tracer; fresh state per call."""
    from repro.query import physical
    table = Table.synthetic("events", 8192,
                            {f"c{i:02d}": 8 for i in range(8)}, seed=0)
    enc = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=0.016)
    qtrace = make_trace(table, TraceSpec(n_queries=n_queries, skew=1.2,
                                         seed=11))
    clean_s = (enc.nbytes
               / sum(len(c.chunks) for c in enc.columns.values())
               / tiers.fast.bandwidth)
    chaos = ChaosHarness(
        FaultSpec(seed=42, stall_rate=0.1, corrupt_rate=0.05),
        guard=ChunkGuard(enc),
        retry=RetryPolicy(timeout_s=2.0 * clean_s,
                          backoff_s=0.5 * clean_s, max_retries=2))
    chaos.inject_corruption()
    bytes_typ = sum(
        physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                  enc.columns)
        for tq in qtrace) / len(qtrace)
    sla_s = 2.5 * bytes_typ / tiers.fast.bandwidth
    tracer = Tracer()
    monitor = SLOMonitor(target=0.9, cadence_s=sla_s / 2)
    pe, eng, att = replay_trace(
        enc, qtrace, tiers, Policy.CACHE, sla_s=sla_s,
        chunk_rows=CHUNK_ROWS, chaos=chaos,
        prefetch_bytes=table.nbytes // 16, tracer=tracer, monitor=monitor)
    monitor.tick(eng.clock() + monitor.max_window_s)
    return monitor, tracer, pe, eng, att


# --------------------------------------------------------------------------
# critical-path reconciliation across execution paths
# --------------------------------------------------------------------------

def _assert_paths_close(attr, tracer):
    """Every path tiles [submitted_at, t_end] and splits bytes exactly."""
    assert attr.ok, attr.problems
    for cp, qt in zip(attr.paths, tracer.queries):
        assert cp.ok, cp.problems
        assert set(cp.seconds_by_category()) <= set(CATEGORIES)
        path_s = sum(seg.dur_s for seg in cp.segments)
        assert path_s == pytest.approx(qt.t_end - qt.submitted_at,
                                       rel=1e-9, abs=1e-12)
        got = dict(cp.on_path_bytes)
        for key, n in cp.off_path_bytes.items():
            got[key] = got.get(key, 0) + n
        assert got == qt.bytes_by_ledger()    # exact int equality


def test_critical_path_plain():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng)
    attr = verify(tracer, pe.meter)
    _assert_paths_close(attr, tracer)
    assert attr.queries == 4 and attr.missed == 0
    for cp in attr.paths:
        assert any(seg.category == "queue" for seg in cp.segments)
    # no pipeline, no chaos, no cap: only queue + tier reads on the path
    assert set(attr.seconds) <= {"queue", "fast_read", "capacity_read"}


def test_critical_path_encoded():
    enc = EncodedTable.from_table(make_table(), chunk_rows=CHUNK_ROWS)
    eng, pe, tracer = tiered_engine(enc)
    run_queries(eng)
    _assert_paths_close(verify(tracer, pe.meter), tracer)


def test_critical_path_sharded():
    st = ShardedTable.shard(make_table(), make_mesh((1,), ("data",)))
    eng, pe, tracer = tiered_engine(st)
    run_queries(eng)
    _assert_paths_close(verify(tracer, pe.meter), tracer)


def test_critical_path_grouped_shape():
    enc = EncodedTable.from_table(make_table(), chunk_rows=CHUNK_ROWS)
    eng, pe, tracer = tiered_engine(enc)
    q = GroupBy(keys=("c00",), aggs=("c01",), where=Pred("c02", "ge", 4))
    assert eng.submit(q, deadline=eng.clock() + 100.0) is not None
    eng.run()
    attr = verify(tracer, pe.meter)
    _assert_paths_close(attr, tracer)
    # the engine stamped the query shape for per-shape diffs
    assert tracer.queries[0].shape == "grouped"
    assert all(shape == "grouped" for shape, _ in attr.shape_seconds)


def test_critical_path_prefetch():
    table = make_table()
    from repro.energy.meter import EnergyMeter
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=10.0)
    pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                   chunk_rows=CHUNK_ROWS,
                                   meter=EnergyMeter(tiers))
    pf = PrefetchPipeline(pe, table.nbytes // 8)
    tracer = Tracer()
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock(), prefetch=pf, tracer=tracer)
    run_queries(eng, n=6)
    attr = verify(tracer, pe.meter)
    _assert_paths_close(attr, tracer)
    # overlap means the path is the max branch per window, never the sum:
    # path time <= the sum of all scan+stream span durations
    for cp, qt in zip(attr.paths, tracer.queries):
        span_sum = sum(sp.dur_s for sp in qt.spans
                       if sp.kind in ("read", "prefetch_read"))
        assert sum(s.dur_s for s in cp.segments) <= span_sum + 1e-12


def test_critical_path_chaos():
    monitor, tracer, pe, eng, att = monitored_chaos_run()
    attr = verify(tracer, pe.meter)
    _assert_paths_close(attr, tracer)
    assert attr.seconds.get("recovery", 0.0) > 0.0
    # under the tight SLA hopeless queries are *rejected at admission*
    # (burning SLO budget — the monitor saw errors) rather than served
    # late, so served queries can all meet while attainment drops
    assert len(eng.queue.rejected) > 0 and att < 1.0
    assert monitor.tenants and any(
        led.errors for led in monitor.tenants.values())
    fr = attr.fractions()
    assert abs(sum(fr.values()) - 1.0) < 1e-9
    assert "SLA-missed" in attr.render()


def test_critical_path_flags_tampered_trace():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng, n=2)
    verify(tracer, pe.meter)
    qt = tracer.queries[0]
    qt.spans[:] = [sp for sp in qt.spans if sp.kind != "admission"]
    cp = critical_path(qt)
    assert not cp.ok
    assert any("admission" in p for p in cp.problems)
    with pytest.raises(ConservationError, match="admission"):
        verify(tracer, pe.meter)


def test_critical_path_unserved_query():
    qt = SimpleNamespace(qid=7, tenant=0, shape="scan", met=None,
                         degraded=False, submitted_at=1.0, t_start=None,
                         t_end=None, spans=[], reads=[])
    cp = critical_path(qt)
    assert not cp.ok and cp.total_s == 0.0
    assert any("never served" in p for p in cp.problems)


# --------------------------------------------------------------------------
# SLO burn-rate monitoring
# --------------------------------------------------------------------------

def test_slo_alerts_byte_identical_across_replays():
    m1 = monitored_chaos_run()[0]
    m2 = monitored_chaos_run()[0]
    assert m1.alerts_json() == m2.alerts_json()
    assert len(m1.alerts) > 0, "chaos run burned no budget — dead test"
    # computed timestamps: every alert sits exactly on a cadence tick
    for a in m1.alerts:
        k = round(a.t / m1.cadence_s)
        assert a.t == k * m1.cadence_s


def test_slo_fire_and_resolve():
    mon = SLOMonitor(target=0.9, cadence_s=1.0)
    mon.tick(0.0)
    bad = SimpleNamespace(met=False)
    good = SimpleNamespace(met=True)
    mon.observe(bad)
    alerts = mon.tick(1.0)
    # 100% errors / 10% budget = burn 10 >= both thresholds: both fire
    assert [a.kind for a in alerts] == ["fire", "fire"]
    assert {a.rule for a in alerts} == {"fast_burn", "slow_burn"}
    assert alerts[0].t == 1.0
    assert alerts[0].burn_long == pytest.approx(10.0)
    for _ in range(40):
        mon.observe(good)
    alerts = mon.tick(3.0)
    # the short windows go quiet -> both rules resolve
    assert [a.kind for a in alerts] == ["resolve", "resolve"]
    assert mon.summary()["firing"] == []
    budget = mon.error_budget(0)
    assert budget["events"] == 41 and budget["errors"] == 1


def test_slo_rejection_burns_budget():
    mon = SLOMonitor(target=0.9, cadence_s=1.0)
    mon.observe_rejected(tenant=3)
    b = mon.error_budget(3)
    assert b["events"] == 1 and b["errors"] == 1
    assert b["remaining_fraction"] < 0       # over budget
    assert mon.error_budget(99)["remaining_fraction"] == 1.0


def test_slo_engine_rejection_and_gauges():
    table = make_table()
    mon = SLOMonitor(target=0.9, cadence_s=1e-5)
    eng, pe, tracer = tiered_engine(table, monitor=mon)
    run_queries(eng, n=4)
    # an infeasible deadline is rejected at admission and lands in the
    # tenant ledger automatically
    q = Query(Pred("c00", "ge", 10), aggregates=("c01",))
    assert eng.submit(q, deadline=eng.clock()) is None
    led = mon.tenants[0]
    assert led.events == 5 and led.errors == 1
    # engine gauges sampled on the modeled clock
    assert len(mon.series["hit_rate"]) > 0
    assert mon.series["blended_gbps"].last > 0
    assert "watts" not in mon.series         # no power cap wired
    assert eng.summary()["slo"]["ticks"] == mon._next_tick


def test_slo_monitor_requires_tiered():
    with pytest.raises(ValueError, match="tiered"):
        QueryEngine(make_table(), mode="xla_ref", monitor=SLOMonitor())


def test_slo_validation():
    with pytest.raises(ValueError, match="target"):
        SLOMonitor(target=1.0)
    with pytest.raises(ValueError, match="cadence"):
        SLOMonitor(cadence_s=0.0)
    with pytest.raises(ValueError, match="short window"):
        BurnRateRule("bad", long_s=1.0, short_s=2.0, threshold=1.0)
    with pytest.raises(ValueError, match="positive"):
        BurnRateRule("bad", long_s=1.0, short_s=0.5, threshold=0.0)
    fast, slow = default_rules(0.01)
    assert fast.long_s == 0.16 and slow.threshold == 1.5


# --------------------------------------------------------------------------
# ring series + percentile/histogram edge cases
# --------------------------------------------------------------------------

def test_ring_series_basics():
    s = RingSeries("x", capacity=3)
    assert s.last is None and s.last_t is None
    assert s.at_or_before(10.0) is None
    for i in range(4):
        s.push(float(i), float(i * 10))
    assert len(s) == 3                       # oldest sample evicted
    assert s.at_or_before(0.5) is None       # t=0 aged out of the ring
    assert s.at_or_before(2.5) == 20.0
    assert s.last == 30.0 and s.last_t == 3.0
    assert s.window(1.0, 3.0) == [(2.0, 20.0), (3.0, 30.0)]
    assert s.window_mean(1.0, 3.0) == 25.0
    assert s.window_mean(90.0, 99.0) == 0.0  # empty window convention
    with pytest.raises(ValueError, match="before"):
        s.push(2.0, 0.0)
    with pytest.raises(ValueError, match="capacity"):
        RingSeries("x", capacity=0)


def test_latency_percentile_edges():
    assert latency_percentile([], 99) == 0.0
    for q in (0, 50, 99, 100):
        assert latency_percentile([0.7], q) == 0.7
    assert latency_percentile([3.3] * 5, 99) == 3.3   # exactly, no interp
    assert latency_percentile([1.0, 2.0, 3.0], 50) == 2.0


def test_histogram_edges():
    h = Histogram("lat")
    assert h.mean == 0.0
    assert h.as_dict() == {"count": 0, "sum": 0.0, "mean": 0.0,
                           "min": None, "max": None}
    h.observe(2.5)
    assert h.as_dict() == {"count": 1, "sum": 2.5, "mean": 2.5,
                           "min": 2.5, "max": 2.5}
    h2 = Histogram("eq")
    for _ in range(4):
        h2.observe(1.25)
    assert h2.mean == 1.25 and h2.vmin == h2.vmax == 1.25
    with pytest.raises(ValueError, match="finite"):
        h.observe(float("nan"))


# --------------------------------------------------------------------------
# export: golden waterfall + Perfetto schema invariants
# --------------------------------------------------------------------------

def test_waterfall_matches_golden():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng, n=2)
    got = waterfall_query(tracer.queries[0], width=40) + "\n"
    golden = GOLDEN / "waterfall_plain.txt"
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        golden.write_text(got)
    assert got == golden.read_text(), \
        "waterfall drifted from tests/golden/waterfall_plain.txt " \
        "(set REPRO_UPDATE_GOLDEN=1 to regenerate on purpose)"


def test_chrome_trace_schema():
    _, tracer, pe, eng, _ = monitored_chaos_run(n_queries=20)
    j = chrome_trace_json(tracer)
    doc = json.loads(j)
    # sorted keys + fixed separators: the canonical serialization
    assert j == json.dumps(doc, sort_keys=True, separators=(",", ":"))
    # X events are ts-monotone within every (pid, tid) lane, and all
    # metadata precedes all X events
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert phs.index("X") == len([p for p in phs if p == "M"])
    lanes = {}
    for e in doc["traceEvents"]:
        if e["ph"] != "X":
            continue
        last = lanes.get((e["pid"], e["tid"]))
        assert last is None or e["ts"] >= last, \
            f"lane {(e['pid'], e['tid'])} went backwards at {e['name']}"
        lanes[(e["pid"], e["tid"])] = e["ts"]
    assert len(lanes) > 2


# --------------------------------------------------------------------------
# trace-diff digests + the regression explainer
# --------------------------------------------------------------------------

def test_digest_exact_and_derived():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng)
    d = digest(eng, tracer)
    assert d["v"] == 1 and d["exact"] and d["queries"] == 4
    assert d["snapshot"]["sla.served"] == 4
    assert any(k.startswith("scan/") for k in d["categories"])
    json.dumps(d)                            # JSON-safe, always
    d2 = digest(eng)                         # no tracer: ledger-derived
    assert not d2["exact"]
    assert all(k.startswith("all/") for k in d2["categories"])
    assert d2["categories"]["all/fast_read"] > 0


def test_diff_names_dominant_category():
    base_eng, _, base_tr = tiered_engine(make_table(), fast_frac=0.5)
    run_queries(base_eng)
    new_eng, _, new_tr = tiered_engine(make_table(), fast_frac=0.125)
    run_queries(new_eng)
    rep = diff_traces(base_tr, new_tr)
    assert rep.exact
    dom = rep.dominant()
    # a smaller fast tier shows up as capacity reads owning the delta
    assert dom is not None and dom.category == "capacity_read"
    assert dom.delta_s > 0 and rep.delta_total_s > 0
    assert f"dominant regression: {dom.key}" in rep.render()
    # per-query normalization: query counts divide out
    rep2 = diff_digests(digest(base_eng, base_tr),
                        digest(new_eng, new_tr))
    row = {r.key: r for r in rep2.rows}[dom.key]
    assert row.delta_s == pytest.approx(dom.delta_s, rel=1e-12)


def test_diff_no_regression():
    eng, _, tr = tiered_engine(make_table())
    run_queries(eng)
    rep = diff_traces(tr, tr)
    assert rep.dominant() is None
    assert rep.delta_total_s == 0.0
    assert "no category regressed" in rep.render()


def _obs(categories, queries=4, snapshot=None):
    return {"v": 1, "queries": queries, "exact": True,
            "snapshot": snapshot or {}, "categories": categories}


def test_check_regress_explains_category(tmp_path, monkeypatch):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import check_regress
    monkeypatch.setattr(check_regress, "ROOT", tmp_path)
    path = tmp_path / "BENCH_kernels.json"
    path.write_text(json.dumps([
        {"tuned_gbps": 10.0},
        {"tuned_gbps": 10.5,
         "obs": _obs({"scan/capacity_read": 0.4, "scan/fast_read": 0.1},
                     snapshot={"tier.hit_rate": 0.9})},
        {"tuned_gbps": 6.0,
         "obs": _obs({"scan/capacity_read": 1.6, "scan/fast_read": 0.1},
                     snapshot={"tier.hit_rate": 0.4})},
    ]))
    ok, msg = check_regress.check_bench("kernels")
    assert not ok and "REGRESSION" in msg
    assert ("dominant regressing span category: scan/capacity_read"
            in msg)
    assert "tier.hit_rate" in msg            # snapshot deltas rendered
    # --explain mode produces the JSON artifact without gating
    out = tmp_path / "diff.json"
    assert check_regress.main(["kernels", "--explain",
                               "--out", str(out)]) == 0
    payloads = json.loads(out.read_text())
    assert payloads[0]["dominant"] == "scan/capacity_read"
    assert payloads[0]["bench"] == "kernels"


def test_check_regress_without_digest(tmp_path, monkeypatch):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import check_regress
    monkeypatch.setattr(check_regress, "ROOT", tmp_path)
    path = tmp_path / "BENCH_kernels.json"
    path.write_text(json.dumps([{"tuned_gbps": 10.0},
                                {"tuned_gbps": 10.5},
                                {"tuned_gbps": 4.0}]))
    ok, msg = check_regress.check_bench("kernels")
    assert not ok and "no obs digest" in msg
    msg, payload = check_regress.explain_bench("kernels")
    assert payload is None and "SKIP" in msg


# --------------------------------------------------------------------------
# the what-if hook against the decision surface
# --------------------------------------------------------------------------

def test_whatif_consistent_with_surface():
    monitor, tracer, pe, eng, att = monitored_chaos_run()
    attr = attribute(tracer)
    table_bytes = pe.tiers.fast.capacity / 0.25
    bytes_q = (sum(r.bytes_scanned for r in eng.results)
               / len(eng.results))
    wi = whatif_fast_fraction(                # raises on surface drift
        attr, db_bytes=table_bytes, bytes_per_query=bytes_q,
        sla_s=10.0, current_fraction=0.25,
        hit_curve=zipf_hit_curve(8, 1.2),
        fast_gbps=pe.tiers.fast.gbps, capacity_gbps=pe.tiers.capacity.gbps)
    assert wi["current"]["read_s"] > 0
    rows = wi["rows"]
    assert [r["fast_fraction"] for r in rows] \
        == sorted(r["fast_fraction"] for r in rows)
    # more fast tier never slows the estimated read time
    est = [r["est_read_s"] for r in rows]
    assert all(a >= b - 1e-15 for a, b in zip(est, est[1:]))
    assert wi["best"] is not None            # sla_s=10 s is trivially met
    assert wi["best"]["meets_sla"]


def test_whatif_rejects_bad_inputs():
    with pytest.raises(ValueError, match="read-bound"):
        whatif_fast_fraction(
            {"queue": 5.0}, db_bytes=1e9, bytes_per_query=1e6,
            sla_s=0.01, current_fraction=0.5,
            hit_curve=zipf_hit_curve(8, 1.2),
            fast_gbps=10.0, capacity_gbps=1.0)
    with pytest.raises(ValueError, match="current_fraction"):
        whatif_fast_fraction(
            {"fast_read": 1.0}, db_bytes=1e9, bytes_per_query=1e6,
            sla_s=0.01, current_fraction=1.5,
            hit_curve=zipf_hit_curve(8, 1.2),
            fast_gbps=10.0, capacity_gbps=1.0)
