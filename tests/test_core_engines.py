"""Coverage for the roofline/HLO/traffic/advisor/sweep engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core import advisor, roofline, sweep, traffic
from repro.core.hlo import collective_summary, parse_collectives
from repro.core.model import Workload
from repro.core.systems import DIE_STACKED, TRADITIONAL, TiB


class TestHloParser:
    HLO = """
  %ag = f32[2048,5784]{1,0} all-gather(%x), channel_id=5, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = bf16[64,512]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %rs = f32[8,128]{1,0} reduce-scatter(%g), channel_id=2, replica_groups={{0,1,2,3}}, dimensions={0}
  %aa = f32[16,16]{1,0} all-to-all(%y), channel_id=3, replica_groups=[4,2]<=[8]
  %cp = f32[4,4]{1,0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
  %not_a_collective = f32[2,2]{1,0} add(%a, %b)
"""

    def test_parse(self):
        ops = parse_collectives(self.HLO)
        kinds = [o.kind for o in ops]
        assert kinds == ["all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"]
        ag, ar, rs, aa, cp = ops
        assert ag.result_bytes == 2048 * 5784 * 4 and ag.group_size == 16
        assert ar.result_bytes == 64 * 512 * 2 and ar.group_size == 4
        assert rs.group_size == 4
        # ring formulas
        assert ar.ring_bytes == pytest.approx(2 * ar.result_bytes * 3 / 4)
        assert ag.ring_bytes == pytest.approx(ag.result_bytes * 15 / 16)
        assert rs.ring_bytes == pytest.approx(rs.result_bytes * 3)
        assert cp.ring_bytes == cp.result_bytes

    def test_summary(self):
        s = collective_summary(self.HLO)
        assert s["total_count"] == 5
        assert set(s["ops"]) == {"all-gather", "all-reduce",
                                 "reduce-scatter", "all-to-all",
                                 "collective-permute"}


class TestRoofline:
    def test_terms_and_dominance(self):
        t = roofline.terms(197e12, 819e9, 0.0)   # 1s compute, 1s memory
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.dominant in ("compute", "memory")
        t2 = roofline.terms(1e12, 1e9, 500e9)
        assert t2.dominant == "collective"
        assert t2.step_time_s == pytest.approx(10.0)

    def test_extrapolation_is_affine_exact(self):
        cost_p = {"flops": 10.0}
        cost_2p = {"flops": 16.0}    # per-layer 6, base 4
        est = roofline.extrapolate(cost_p, cost_2p, num_layers=10, p=1)
        assert est["flops"] == pytest.approx(4 + 10 * 6)

    def test_model_flops_conventions(self):
        cfg = get_config("mixtral-8x22b")
        train = roofline.model_flops(cfg, SHAPES["train_4k"])
        dec = roofline.model_flops(cfg, SHAPES["decode_32k"])
        assert train == pytest.approx(
            6 * cfg.active_param_count() * 4096 * 256)
        assert dec == pytest.approx(2 * cfg.active_param_count() * 128)
        # MoE: active < total
        assert cfg.active_param_count() < cfg.param_count()


class TestTraffic:
    def test_strategies_move_bytes_where_expected(self):
        cfg = get_config("internlm2-1.8b")
        mesh = traffic.MeshShape.production(False)
        base = traffic.collective_traffic(cfg, SHAPES["train_4k"], mesh,
                                          "megatron")
        dp = traffic.collective_traffic(cfg, SHAPES["train_4k"], mesh, "dp")
        assert base["tp_allreduce"] > 0 and dp["tp_allreduce"] == 0
        assert dp["total"] < base["total"]

    def test_2d_decode_drops_regather(self):
        cfg = get_config("llama3-405b")
        mesh = traffic.MeshShape.production(False)
        base = traffic.hbm_traffic(cfg, SHAPES["decode_32k"], mesh,
                                   "megatron")
        two_d = traffic.hbm_traffic(cfg, SHAPES["decode_32k"], mesh, "2d")
        assert two_d["weights"] < base["weights"] / 10
        coll_b = traffic.collective_traffic(cfg, SHAPES["decode_32k"], mesh,
                                            "megatron")
        coll_2 = traffic.collective_traffic(cfg, SHAPES["decode_32k"], mesh,
                                            "2d")
        assert coll_2["total"] < coll_b["total"] / 50

    def test_moe_ep_alltoall_accounted(self):
        """Fine-grained EP MoE must carry the dispatch all-to-all term
        (and it must vanish when experts are replicated or expert-TP'd)."""
        mesh = traffic.MeshShape.production(False)
        moon = get_config("moonshot-v1-16b-a3b")       # 64e >= 16: EP
        mix = get_config("mixtral-8x22b")              # 8e < 16: expert-TP
        t_moon = traffic.collective_traffic(moon, SHAPES["train_4k"], mesh,
                                            "megatron")
        t_mix = traffic.collective_traffic(mix, SHAPES["train_4k"], mesh,
                                           "megatron")
        assert t_moon["ep_alltoall"] > 0
        # per-layer bytes = 2 * tok_local * k * d * 2B * (g-1)/g * 3 passes
        expect = (moon.num_layers * 2 * (4096 * 256 / 16)
                  * moon.experts_per_token * moon.d_model * 2
                  * (15 / 16) * 3)
        assert t_moon["ep_alltoall"] == pytest.approx(expect)
        assert t_mix["ep_alltoall"] == 0.0
        t_dp = traffic.collective_traffic(moon, SHAPES["train_4k"], mesh,
                                          "dp")
        assert t_dp["ep_alltoall"] == 0.0

    def test_decode_is_bandwidth_bound_everywhere(self):
        """The paper's premise, checked across the zoo: decode arithmetic
        intensity (useful flops / HBM bytes) < ridge point."""
        mesh = traffic.MeshShape.production(False)
        for arch in ("internlm2-1.8b", "llama3-405b", "mamba2-1.3b"):
            cfg = get_config(arch)
            hbm = traffic.hbm_traffic(cfg, SHAPES["decode_32k"], mesh, "2d")
            flops = roofline.model_flops(cfg, SHAPES["decode_32k"]) / 256
            intensity = flops / hbm["total"]
            assert intensity < 240, (arch, intensity)  # ridge ~ 240 FLOP/B


class TestAdvisor:
    def test_decode_workload_mapping(self):
        cfg = get_config("llama3-405b")
        wl = advisor.lm_decode_workload(cfg, batch=128, seq_len=32768)
        assert wl.db_size > 2 * cfg.param_count()     # params + cache
        assert 0 < wl.percent_accessed <= 1.0

    def test_sla_advice_meets_sla(self):
        cfg = get_config("mixtral-8x22b")
        for sla in (0.005, 0.050):
            a = advisor.advise_decode_sla(cfg, 128, 32768, sla)
            assert a.design.response_time <= sla * 1.001
            assert a.design.holds_workload

    def test_when_to_use_tpu_shape(self):
        rows = advisor.when_to_use_tpu(get_config("internlm2-1.8b"),
                                       128, 32768, slas=(0.005, 0.5))
        assert len(rows) == 2
        # tight SLA should favor the high-bandwidth system (paper Fig. 3)
        assert rows[0]["tpu_wins_power"] or rows[0]["host_overprovision_x"] > 5


class TestSweep:
    def test_hard_sweep_matches_scalar_model(self):
        from repro.core import provision_performance
        wl = Workload(16 * TiB, 0.20)
        slas = np.array([0.01, 0.05, 0.1, 0.5, 1.0])
        vec = sweep.sweep_performance(TRADITIONAL, wl, slas)
        for i, sla in enumerate(slas):
            scalar = provision_performance(TRADITIONAL, wl, float(sla)).power
            assert abs(float(vec[i]) - scalar) / scalar < 0.02, sla

    def test_soft_model_is_differentiable_and_close(self):
        wl = Workload(16 * TiB, 0.20)
        hard = sweep.soft_performance_power(DIE_STACKED, wl, 0.01, hard=True)
        soft = sweep.soft_performance_power(DIE_STACKED, wl, 0.01)
        assert abs(float(hard) - float(soft)) / float(hard) < 0.05
        g = sweep.power_sensitivity(DIE_STACKED, wl, 0.01)
        # denser die-stacks cut power (fewer chips): negative gradient
        assert g["d_power_d_log_density"] < 0
        # cheaper cores cut power linearly in compute share: positive w.r.t.
        # core power scale
        assert g["d_power_d_log_core_power"] > 0
