"""SSD chunk kernel: interpret-mode sweeps vs the chunk oracle AND the
full model implementation (repro.models.ssm) — three-way agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk import ops as ssd_ops
from repro.kernels.ssd_chunk import ref as ssd_ref


def make_inputs(key, b, s, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    bm = jax.random.normal(ks[2], (b, s, n), jnp.float32).astype(dtype) / n**0.5
    cm = jax.random.normal(ks[3], (b, s, n), jnp.float32).astype(dtype) / n**0.5
    return x, dt, a_log, bm, cm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 64, 32, 32),
    (2, 128, 4, 64, 128, 64),
    (1, 256, 2, 128, 64, 128),
])
def test_kernel_matches_chunk_ref(dtype, b, s, h, p, n, chunk):
    x, dt, a_log, bm, cm = make_inputs(jax.random.PRNGKey(0), b, s, h, p, n,
                                       dtype)
    y_k, h_k = ssd_ops.ssd(x, dt, a_log, bm, cm, chunk, mode="pallas")
    y_r, h_r = ssd_ops.ssd(x, dt, a_log, bm, cm, chunk, mode="xla_ref")
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-3, atol=1e-3)


def test_kernel_matches_model_ssd():
    """Three-way: kernel == chunk oracle == the model's _ssd_chunked."""
    from repro.models import ssm
    from repro.configs import get_config

    cfg = get_config("mamba2-1.3b").reduced(dtype="float32", ssm_chunk=32)
    b, s = 2, 128
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x, dt, a_log, bm, cm = make_inputs(jax.random.PRNGKey(1), b, s, h, p, n)

    y_model, h_model = ssm._ssd_chunked(x, dt, a_log, bm, cm, cfg)
    y_kernel, h_kernel = ssd_ops.ssd(x, dt, a_log, bm, cm, cfg.ssm_chunk)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_model),
                               np.asarray(jnp.swapaxes(h_kernel, 2, 2)),
                               rtol=1e-3, atol=1e-3)


def test_chunk_independence():
    """Chunk size must not change the math (32 vs 128)."""
    x, dt, a_log, bm, cm = make_inputs(jax.random.PRNGKey(2), 1, 256, 2, 64,
                                       32)
    y1, h1 = ssd_ops.ssd(x, dt, a_log, bm, cm, 32)
    y2, h2 = ssd_ops.ssd(x, dt, a_log, bm, cm, 128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-3, atol=1e-3)
