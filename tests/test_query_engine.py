"""Query-engine tests: plan shapes, mode parity, sharding, SLA batching.

Parity contract (ISSUE 2): every plan shape — AND/OR, mixed code widths,
sharded vs single-device — produces identical results under
KernelMode.PALLAS and KernelMode.XLA_REF, and matches a numpy oracle over
the decoded values.
"""
import math

import numpy as np
import pytest

from repro.db import Table
from repro.launch.mesh import make_mesh
from repro.query import And, Or, Pred, Query, QueryEngine, ShardedTable
from repro.query.plan import normalize

MODES = ("pallas", "xla_ref", "auto")

# 10_001 rows: not a multiple of any codes-per-word, so every column carries
# tail padding — the validity masks must cancel it under every plan shape
N_ROWS = 10_001
SPEC = {"a": 8, "b": 8, "w": 16, "x": 4}


@pytest.fixture(scope="module")
def table():
    return Table.synthetic("t", N_ROWS, SPEC, seed=3)


@pytest.fixture(scope="module")
def decoded(table):
    return {c: table.columns[c].decode() for c in SPEC}


def oracle(decoded, sel, agg):
    vals = decoded[agg][sel]
    vmax = (1 << (SPEC[agg] - 1)) - 1
    return {"sum": int(vals.sum()) if sel.any() else 0,
            "count": int(sel.sum()),
            "min": int(vals.min()) if sel.any() else vmax,
            "max": int(vals.max()) if sel.any() else 0}


PLAN_SHAPES = [
    # (name, plan factory, numpy selection factory, aggregates)
    # -- same-width single-pred/single-agg shapes take the fused kernel;
    #    cover every composition primitive (ge direct, lt/ne inverted,
    #    gt via constant+1, eq) --
    ("single_pred_fused", lambda: Pred("a", "lt", 50),
     lambda d: d["a"] < 50, ("b",)),
    ("fused_ge", lambda: Pred("a", "ge", 100),
     lambda d: d["a"] >= 100, ("b",)),
    ("fused_gt", lambda: Pred("a", "gt", 100),
     lambda d: d["a"] > 100, ("b",)),
    ("fused_eq", lambda: Pred("a", "eq", 64),
     lambda d: d["a"] == 64, ("b",)),
    ("fused_ne", lambda: Pred("a", "ne", 64),
     lambda d: d["a"] != 64, ("b",)),
    ("and_same_width", lambda: Pred("a", "lt", 50) & Pred("b", "ge", 100),
     lambda d: (d["a"] < 50) & (d["b"] >= 100), ("b",)),
    ("and_mixed_width", lambda: Pred("a", "lt", 50) & Pred("w", "ge", 9000),
     lambda d: (d["a"] < 50) & (d["w"] >= 9000), ("w",)),
    ("or_mixed_width", lambda: Pred("x", "eq", 3) | Pred("w", "lt", 500),
     lambda d: (d["x"] == 3) | (d["w"] < 500), ("a",)),
    ("nested_and_or",
     lambda: And.of(Or.of(Pred("a", "le", 20), Pred("b", "gt", 120)),
                    Pred("x", "ne", 0)),
     lambda d: ((d["a"] <= 20) | (d["b"] > 120)) & (d["x"] != 0), ("b",)),
    ("multi_agg_mixed", lambda: Pred("a", "ge", 64),
     lambda d: d["a"] >= 64, ("b", "w", "x")),
    ("empty_selection", lambda: Pred("x", "gt", 7),
     lambda d: d["x"] > 7, ("a",)),
]


@pytest.mark.parametrize("name,mkplan,mksel,aggs",
                         PLAN_SHAPES, ids=[p[0] for p in PLAN_SHAPES])
def test_plan_shape_parity_all_modes(table, decoded, name, mkplan, mksel,
                                     aggs):
    sel = mksel(decoded)
    want = {a: oracle(decoded, sel, a) for a in aggs}
    got_by_mode = {}
    for mode in MODES:
        eng = QueryEngine(table, mode=mode)
        eng.submit(Query(mkplan(), aggregates=aggs))
        res = eng.run()[0]
        assert res.aggregates == want, (name, mode)
        got_by_mode[mode] = res.aggregates
        assert res.count == int(sel.sum())
    assert got_by_mode["pallas"] == got_by_mode["xla_ref"]


@pytest.mark.parametrize("name,mkplan,mksel,aggs",
                         PLAN_SHAPES, ids=[p[0] for p in PLAN_SHAPES])
def test_sharded_matches_single_device(table, decoded, name, mkplan, mksel,
                                       aggs):
    """1-device mesh in-process; the 8-device run lives in
    tests/multidevice_child.py (device count locks at first jax init)."""
    mesh = make_mesh((1,), ("data",))
    st = ShardedTable.shard(table, mesh)
    sel = mksel(decoded)
    want = {a: oracle(decoded, sel, a) for a in aggs}
    for mode in ("pallas", "xla_ref"):
        eng = QueryEngine(st, mode=mode)
        eng.submit(Query(mkplan(), aggregates=aggs))
        assert eng.run()[0].aggregates == want, (name, mode)


def test_empty_table_returns_identity():
    """Zero-row tables execute cleanly (regression: zero-row Pallas grid
    divided by zero) and return the empty-selection identity."""
    t = Table.synthetic("empty", 0, {"a": 8, "b": 8})
    q = Query(Pred("a", "lt", 5), aggregates=("b",))
    for mode in ("pallas", "xla_ref"):
        eng = QueryEngine(t, mode=mode)
        eng.submit(q)
        res = eng.run()[0]
        assert res.aggregates["b"] == {"sum": 0, "count": 0, "min": 127,
                                       "max": 0}
        assert res.count == 0 and res.selectivity == 0


def test_engine_sum_exact_beyond_int32():
    """A 16-bit column over a few hundred k rows sums past 2^31: the
    engine must report the exact value, single-device and sharded."""
    t = Table.synthetic("big", 300_000, {"p": 16}, seed=5)
    want = int(t.columns["p"].decode().astype(np.int64).sum())
    assert want > 2**31
    q = Query(Pred("p", "ge", 0), aggregates=("p",))
    for tbl in (t, ShardedTable.shard(t, make_mesh((1,), ("data",)))):
        eng = QueryEngine(tbl, mode="auto")
        eng.submit(q)
        res = eng.run()[0]
        assert res.aggregates["p"]["sum"] == want
        assert res.count == 300_000


class TestPlanLayer:
    def test_operators_build_flattened_trees(self):
        p = Pred("a", "lt", 3) & Pred("b", "ge", 1) & Pred("x", "eq", 2)
        assert isinstance(p, And) and len(p.children) == 3
        q = Pred("a", "lt", 3) | Pred("b", "ge", 1)
        assert isinstance(q, Or) and len(q.children) == 2

    def test_bad_op_raises(self):
        with pytest.raises(ValueError, match="unknown predicate op"):
            Pred("a", "like", 3)

    def test_negative_constant_raises(self):
        with pytest.raises(ValueError, match="negative"):
            Pred("a", "lt", -1)

    def test_empty_aggregates_raises(self):
        with pytest.raises(ValueError, match="aggregate"):
            Query(Pred("a", "lt", 3), aggregates=())

    def test_normalize_legacy_list_is_conjunction(self):
        plan = normalize([Pred("a", "lt", 3), Pred("b", "ge", 1)])
        assert isinstance(plan, And)
        with pytest.raises(ValueError, match="at least one predicate"):
            normalize([])

    def test_unknown_column_raises_at_submit(self, table):
        eng = QueryEngine(table)
        with pytest.raises(ValueError, match="unknown column"):
            eng.submit(Query(Pred("nope", "lt", 3), aggregates=("a",)))

    def test_constant_beyond_payload_raises(self, table):
        eng = QueryEngine(table)
        with pytest.raises(ValueError, match="payload max"):
            eng.submit(Query(Pred("x", "lt", 99), aggregates=("a",)))


class TestEngineSLA:
    class Clock:
        """Deterministic clock advancing a tick per observation."""

        def __init__(self, tick=0.01):
            self.t = 0.0
            self.tick = tick

        def __call__(self):
            self.t += self.tick
            return self.t

    def test_infeasible_deadline_rejected(self, table):
        clock = self.Clock()
        # 1e-6 GB/s => any query estimates ~minutes of service time
        eng = QueryEngine(table, clock=clock, est_gbps=1e-6)
        qid = eng.submit(Query(Pred("a", "lt", 50), aggregates=("b",)),
                         deadline=0.001)
        assert qid is None
        assert eng.rejected == [1]
        assert eng.run() == []

    def test_edf_order_and_reports(self, table):
        eng = QueryEngine(table, clock=self.Clock(),
                          est_gbps=1e9)          # everything feasible
        q = Query(Pred("a", "lt", 50), aggregates=("b",))
        ids = [eng.submit(q, deadline=d) for d in (math.inf, 500.0, 100.0)]
        results = eng.run()
        assert [r.qid for r in results] == [ids[2], ids[1], ids[0]]
        s = eng.summary()
        assert s["served"] == 3 and s["rejected"] == 0
        assert s["sla_attainment"] == 1.0
        assert s["latency_p99_s"] >= s["latency_p50_s"] > 0
        assert s["measured_gbps"] > 0

    def test_measured_throughput_feeds_admission(self, table):
        eng = QueryEngine(table, est_gbps=1e9)
        eng.submit(Query(Pred("a", "lt", 50), aggregates=("b",)))
        eng.run()
        assert eng.measured_bps == pytest.approx(
            eng.bytes_total / eng.seconds_total)

    def test_model_check_and_provision(self, table):
        eng = QueryEngine(table)
        eng.submit(Query(Pred("a", "lt", 50), aggregates=("b",)))
        eng.run()
        mc = eng.model_check()
        assert mc["chips"] == 1
        assert 0 < mc["measured_gbps"]
        assert 0 < mc["attained_fraction"] < 1   # interpret mode << model
        adv = eng.provision(sla_s=0.1)
        assert adv.design.compute_chips >= 1
        assert adv.design.response_time <= 0.1 * 1.01

    def test_model_check_before_any_query_raises(self, table):
        """Regression: zero measured throughput is a degenerate model
        comparison, not a silent row of zeros."""
        with pytest.raises(ValueError, match="model_check"):
            QueryEngine(table).model_check()

    def test_calibration_guards_degenerate_throughput(self):
        from repro.core.advisor import calibrated_system
        from repro.core.systems import DIE_STACKED
        for bad in (0.0, -5.0, float("nan"), float("inf")):
            with pytest.raises(ValueError, match="degenerate"):
                calibrated_system(DIE_STACKED, bad)
        ok = calibrated_system(DIE_STACKED, 8e9)
        assert ok.chip_peak_perf == pytest.approx(8e9)


class TestLegacyWrappers:
    """db.queries routes through the same execution path."""

    def test_scan_query_mask_layout(self, table, decoded):
        from repro.db.queries import scan_query
        from repro.kernels.scan_filter.ref import unpack_mask
        mask = scan_query(table, [Pred("a", "lt", 50), Pred("w", "ge", 9000)])
        sel = np.asarray(unpack_mask(mask, 8))[:N_ROWS]
        np.testing.assert_array_equal(
            sel, (decoded["a"] < 50) & (decoded["w"] >= 9000))

    def test_tail_padding_never_matches(self):
        """Seed bug: pack() tail codes (value 0) matched lt/le predicates."""
        from repro.db.queries import scan_aggregate_query
        t = Table.synthetic("tail", 10, {"a": 8, "b": 8}, seed=0)
        av, bv = t.columns["a"].decode(), t.columns["b"].decode()
        r = scan_aggregate_query(t, [Pred("a", "le", 127)], "b")
        assert int(r["count"]) == 10          # not 12 (2 pad codes)
        assert int(r["sum"]) == int(bv.sum())
