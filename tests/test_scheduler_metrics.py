"""SLA scheduler + metrics logger tests."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.models import lm
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import SLAScheduler
from repro.serve.sla import VirtualClock
from repro.train.metrics import MetricsLogger


class TestVirtualClock:
    """Edge cases of the modeled time axis every tiered/energy deadline
    experiment runs on."""

    def test_zero_duration_advance_is_identity(self):
        clk = VirtualClock(5.0)
        assert clk.advance(0.0) == 5.0
        assert clk() == 5.0

    def test_monotone_under_interleaved_advances(self):
        clk = VirtualClock()
        rng = np.random.default_rng(0)
        seen = [clk()]
        for dt in rng.gamma(1.0, 0.01, size=100):
            clk.advance(float(dt))
            seen.append(clk())
            clk.advance(0.0)                # interleaved no-ops
            seen.append(clk())
        assert (np.diff(seen) >= 0).all()
        assert clk() == pytest.approx(clk.now)

    def test_rejects_backwards_and_nonfinite_time(self):
        clk = VirtualClock(1.0)
        for bad in (-1e-12, -1.0, float("nan"), float("inf")):
            with pytest.raises(ValueError, match="advance"):
                clk.advance(bad)
        assert clk() == 1.0                 # rejected advances don't move it


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2-1.8b").reduced(dtype="float32", num_layers=2)
    params, _ = lm.init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(cfg, params, batch_slots=2, max_len=64)


class TestSLAScheduler:
    def test_infeasible_requests_rejected_upfront(self, engine):
        clock = FakeClock()
        sched = SLAScheduler(engine, decode_rate_tps=10.0, clock=clock)
        req = Request(rid=1, prompt=np.array([3, 4], np.int32),
                      max_new_tokens=100)
        # 100 tokens at 10 tok/s = 10s > 1s deadline
        assert not sched.submit(req, deadline=1.0)
        assert sched.rejected == [1]

    def test_feasible_requests_served_and_reported(self, engine):
        clock = FakeClock()
        sched = SLAScheduler(engine, decode_rate_tps=1e9, clock=clock)
        rng = np.random.default_rng(0)
        for i in range(4):
            ok = sched.submit(
                Request(rid=i, prompt=rng.integers(0, 200, 4),
                        max_new_tokens=3),
                deadline=1e9)
            assert ok
        reports = sched.run()
        assert sorted(r.rid for r in reports) == [0, 1, 2, 3]
        s = sched.summary()
        assert s["served"] == 4 and s["rejected"] == 0
        assert s["sla_attainment"] == 1.0
        assert s["tokens"] == 4 * 3

    def test_edf_ordering(self, engine):
        clock = FakeClock()
        sched = SLAScheduler(engine, decode_rate_tps=1e9, clock=clock)
        rng = np.random.default_rng(1)
        # submit in reverse-deadline order; both slots busy with 2 first
        for rid, dl in ((0, 500.0), (1, 400.0), (2, 100.0), (3, 200.0)):
            sched.submit(Request(rid=rid, prompt=rng.integers(0, 200, 3),
                                 max_new_tokens=2), deadline=dl)
        # queue (beyond the 2 slots) must pop earliest-deadline-first
        order = [r.rid for r in sched.queue.ordered_items()]
        assert order == [2, 3, 1, 0]
        sched.run()
        assert sched.summary()["served"] == 4

    def test_summary_reports_latency_percentiles(self, engine):
        clock = FakeClock()
        sched = SLAScheduler(engine, decode_rate_tps=1e9, clock=clock)
        rng = np.random.default_rng(2)
        for i in range(3):
            sched.submit(Request(rid=i, prompt=rng.integers(0, 200, 3),
                                 max_new_tokens=2), deadline=1e9)
            clock.t += 1.0                   # staggered arrivals
        sched.run()
        s = sched.summary()
        # all finish together; latencies are the staggered waits 1s/2s/3s
        assert s["latency_p50_s"] == pytest.approx(2.0)
        longest = max(r.latency_s for r in sched.reports)
        assert s["latency_p50_s"] < s["latency_p99_s"] <= longest

    def test_zero_decode_rate_is_guarded(self, engine):
        """Seed bug: _admit divided by self.rate unguarded -> ZeroDivision
        when decode_rate_tps=0 (unknown rate). Now: a zero rate estimates
        infinitely slow decode, so finite deadlines reject upfront and
        deadline-free requests still run."""
        clock = FakeClock()
        sched = SLAScheduler(engine, decode_rate_tps=0.0, clock=clock)
        rng = np.random.default_rng(3)
        assert not sched.submit(
            Request(rid=0, prompt=rng.integers(0, 200, 3),
                    max_new_tokens=2), deadline=1e9)
        ok = sched.submit(Request(rid=1, prompt=rng.integers(0, 200, 3),
                                  max_new_tokens=2),
                          deadline=float("inf"))
        assert ok
        reports = sched.run()                # must not raise
        assert [r.rid for r in reports] == [1]
        assert sched.rejected == [0]


class TestMetricsLogger:
    def test_logs_mfu_and_roofline_gap(self, tmp_path):
        cfg = get_config("internlm2-1.8b")
        shape = ShapeSpec("t", "train", 4096, 256)
        log = MetricsLogger(tmp_path / "m.jsonl", cfg, shape, chips=256,
                            strategy="dp")
        rec = log.log(1, seconds=0.5, metrics={"loss": 3.25})
        log.log(2, seconds=0.4, metrics={"loss": 3.0})
        log.close()
        lines = [json.loads(l) for l in
                 (tmp_path / "m.jsonl").read_text().splitlines()]
        assert len(lines) == 2
        assert lines[0]["loss"] == 3.25
        assert 0 < rec["mfu"] < 1.0
        assert rec["roofline_step_s"] and rec["roofline_gap"] > 0
        assert lines[1]["step_s_ewma"] < lines[0]["step_s_ewma"]
        # tokens/sec sanity: tokens_per_step / step_s
        assert lines[0]["tokens_per_s"] == pytest.approx(4096 * 256 / 0.5)
