"""Energy & cost engine (repro.energy) + query-path integration.

The load-bearing guarantees:
- the EnergyMeter ledger bills every query its per-tier memory joules plus
  compute x busy time, tagged qid/tenant, and its memory_j is exactly the
  old PlacementEngine.energy_j_total scalar;
- a PowerCap-governed replay NEVER exceeds its watt budget over ANY
  sliding window (exact check, property-tested on seeded random streams)
  while still reporting SLA attainment — power-infeasible queries are
  rejected at admission, not silently run over budget;
- decision_surface reproduces the paper's qualitative verdict on
  datasheet inputs: die-stacking wins strict SLAs (<= 10 ms), loses on
  power at relaxed SLAs, crossover consistent with power_crossover_sla;
- cross-checks tie core.provisioning.power_crossover_sla to the fig4
  power-provisioning benchmark and the TCO model at one operating point.
"""
import json
import math
import os
import re
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (DIE_STACKED, TRADITIONAL, Workload,
                        power_crossover_sla, provision_performance,
                        provision_power)
from repro.core.advisor import advise_cost
from repro.core.systems import TiB
from repro.db import Table
from repro.energy import (CostSheet, EnergyMeter, PowerCap,
                          cheapest_architecture, chip_compute_watts,
                          decision_surface, evaluate_system,
                          evaluate_tiered, usd_per_query)
from repro.query import Pred, Query, QueryEngine
from repro.serve.sla import VirtualClock
from repro.tier import (PlacementEngine, Policy, TraceSpec, make_trace,
                        paper_tiers, replay_trace)

WL = Workload(16 * TiB, 0.20)
DB, BPQ = 16 * TiB, 0.20 * 16 * TiB


@pytest.fixture(scope="module")
def table():
    return Table.synthetic("energy", 4096,
                           {f"c{i:02d}": 8 for i in range(8)}, seed=1)


@pytest.fixture(scope="module")
def tiers(table):
    return paper_tiers(table.nbytes * 0.25, fast_gbps=0.016)


# --------------------------------------------------------------------------
# meter: the joules ledger
# --------------------------------------------------------------------------
class TestEnergyMeter:
    def test_charge_components(self, tiers):
        m = EnergyMeter(tiers, compute_w=2.0)
        ch = m.charge(1000, 500, qid=7, tenant=3)
        assert ch.fast_j == pytest.approx(1000 * tiers.fast.energy_per_byte)
        assert ch.capacity_j == pytest.approx(
            500 * tiers.capacity.energy_per_byte)
        assert ch.compute_j == 0.0
        m.charge_compute(ch, busy_s=0.5, chips=4)
        assert ch.compute_j == pytest.approx(2.0 * 4 * 0.5)
        assert ch.total_j == pytest.approx(ch.fast_j + ch.capacity_j
                                           + ch.compute_j)
        assert ch.as_dict()["qid"] == 7

    def test_by_tenant_bill(self, tiers):
        m = EnergyMeter(tiers)
        m.charge(100, 0, tenant=0)
        m.charge(200, 0, tenant=1)
        m.charge(300, 0, tenant=1)
        bill = m.by_tenant()
        assert bill[1]["queries"] == 2
        assert bill[1]["total_j"] == pytest.approx(
            500 * tiers.fast.energy_per_byte)
        assert m.summary()["queries"] == 3
        assert m.total_j == pytest.approx(m.memory_j)   # compute_w=0

    def test_chip_compute_watts_from_table1(self):
        # die-stacked: 32 saturating cores x 3 W
        assert chip_compute_watts(DIE_STACKED) == pytest.approx(96.0)
        with pytest.raises(ValueError, match="cores"):
            chip_compute_watts(DIE_STACKED, cores=0)

    def test_meter_guards_inputs(self, tiers):
        with pytest.raises(ValueError, match="compute_w"):
            EnergyMeter(tiers, compute_w=-1.0)
        with pytest.raises(ValueError, match="compute_w"):
            EnergyMeter(tiers, compute_w=float("nan"))
        m = EnergyMeter(tiers)
        with pytest.raises(ValueError, match="fast_bytes"):
            m.charge(-1, 0)
        with pytest.raises(ValueError, match="busy_s"):
            m.charge_compute(m.charge(1, 1), busy_s=-0.1)


class TestEnergyValidation:
    """Satellite: non-finite/negative inputs rejected with actionable
    errors in TierPair.energy_j and serve.sla.blended_bps."""

    def test_energy_j_rejects_bad_bytes(self, tiers):
        for bad in (-1, float("nan"), float("inf")):
            with pytest.raises(ValueError, match="finite non-negative"):
                tiers.energy_j(bad, 0)
            with pytest.raises(ValueError, match="capacity_bytes"):
                tiers.energy_j(0, bad)
        assert tiers.energy_j(0, 0) == 0.0

    def test_blended_bps_rejects_nonfinite(self):
        from repro.serve.sla import blended_bps
        with pytest.raises(ValueError, match="finite"):
            blended_bps(float("nan"), 4e9, 0.5)
        with pytest.raises(ValueError, match="finite"):
            blended_bps(1e9, float("inf"), 0.5)
        with pytest.raises(ValueError, match="fast_fraction"):
            blended_bps(1e9, 4e9, float("nan"))


# --------------------------------------------------------------------------
# caps: the sliding-window governor
# --------------------------------------------------------------------------
class TestPowerCap:
    def test_guards_construction_and_record(self):
        with pytest.raises(ValueError, match="budget_w"):
            PowerCap(0.0, 1.0)
        with pytest.raises(ValueError, match="window_s"):
            PowerCap(10.0, float("inf"))
        cap = PowerCap(10.0, 1.0)
        with pytest.raises(ValueError, match="forward"):
            cap.record(2.0, 1.0, 1.0)
        with pytest.raises(ValueError, match="joules"):
            cap.record(0.0, 1.0, -1.0)
        with pytest.raises(ValueError, match="zero-length"):
            cap.record(1.0, 1.0, 5.0)
        cap.record(0.0, 1.0, 5.0)
        with pytest.raises(ValueError, match="time-ordered"):
            cap.record(-1.0, 2.0, 1.0)

    def test_max_window_watts_exact(self):
        cap = PowerCap(100.0, 1.0)
        cap.record(0.0, 0.5, 10.0)          # 20 W for 0.5 s
        assert cap.max_window_watts() == pytest.approx(10.0)  # 10 J / 1 s
        # a second burst 0.25 s later: worst window holds both fully
        cap.record(0.75, 1.0, 10.0)
        assert cap.max_window_watts() == pytest.approx(20.0)
        # window ending at 1.0 holds both: 20 J / 1 s
        assert cap.watts(1.0) == pytest.approx(20.0)
        # a distant burst never shares a window
        cap.record(10.0, 10.5, 10.0)
        assert cap.max_window_watts() == pytest.approx(20.0)

    def test_throttle_floor_is_e_over_budget(self):
        """A lone query hotter than the whole window budget must stretch
        to joules/budget; a cooler one keeps its natural service."""
        cap = PowerCap(budget_w=10.0, window_s=1.0)
        assert cap.throttled_service_s(0.0, 5.0, 0.01) == pytest.approx(
            0.01)                           # 5 J < 10 J per window
        s = cap.throttled_service_s(0.0, 25.0, 0.01)
        assert s == pytest.approx(25.0 / 10.0, rel=1e-6)
        assert cap.throttled_service_s(0.0, 0.0, 0.25) == 0.25

    def test_congested_window_stretches_follower(self):
        """After a burst that fills the budget, the next query must slide
        its energy out of the shared window."""
        cap = PowerCap(budget_w=10.0, window_s=1.0)
        s0 = cap.throttled_service_s(0.0, 10.0, 0.1)
        cap.record(0.0, s0, 10.0)
        s1 = cap.throttled_service_s(s0, 5.0, 0.1)
        assert s1 > 0.1                     # the window still holds 10 J
        cap.record(s0, s0 + s1, 5.0)
        assert cap.max_window_watts() <= 10.0 * (1 + 1e-9)

    def test_tiny_service_does_not_collapse_to_zero_segment(self):
        """Regression: a natural service below ulp(now) must not let the
        trial segment collapse to zero length (its joules would vanish
        from the window check and the subsequent record() would raise)."""
        cap = PowerCap(10.0, 1.0)
        cap.record(0.0, 1.0, 10.0)          # window at budget already
        s = cap.throttled_service_s(1.0, 3.0, 0.0)
        assert 1.0 + s > 1.0                # representable at now=1.0
        cap.record(1.0, 1.0 + s, 3.0, natural_s=0.0)   # must not raise
        assert cap.max_window_watts() <= 10.0 * (1 + 1e-9)
        assert cap.throttled_queries == 1
        assert cap.throttle_s_total == pytest.approx(s)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_property_random_stream_never_over_budget(self, seed):
        """Seeded randomized property: any mix of query energies, natural
        service times, and idle gaps, governed then recorded, keeps EVERY
        window at or under budget (exact max, not sampled)."""
        rng = np.random.default_rng(seed)
        budget = float(rng.uniform(5.0, 50.0))
        window = float(rng.uniform(0.1, 2.0))
        cap = PowerCap(budget, window)
        now = 0.0
        for _ in range(60):
            joules = float(rng.gamma(2.0, budget * window / 4))
            natural = float(rng.gamma(2.0, window / 20))
            s = cap.throttled_service_s(now, joules, natural)
            assert s >= natural
            cap.record(now, now + s, joules)
            now += s + (float(rng.exponential(window / 4))
                        if rng.random() < 0.5 else 0.0)
        assert cap.max_window_watts() <= budget * (1 + 1e-9)
        assert len(cap) == 60


# --------------------------------------------------------------------------
# engine integration: metered queries, capped execution, admission feedback
# --------------------------------------------------------------------------
class TestMeteredEngine:
    def run_capped(self, table, tiers, budget_w, sla_s=0.010,
                   n_queries=45, compute_w=1e-3):
        trace = make_trace(table, TraceSpec(n_queries=n_queries, skew=1.1,
                                            seed=5))
        cap = PowerCap(budget_w, window_s=20 * sla_s) \
            if budget_w is not None else None
        pe, eng, att = replay_trace(table, trace, tiers, Policy.MEMCACHE,
                                    sla_s=sla_s, chunk_rows=256,
                                    compute_w=compute_w, power_cap=cap)
        return pe, eng, att, cap

    def test_tenant_tagged_ledger(self, table, tiers):
        pe, eng, att, _ = self.run_capped(table, tiers, None)
        bill = eng.summary()["energy"]["by_tenant"]
        assert set(bill) <= {0, 1, 2, 3}
        assert sum(t["queries"] for t in bill.values()) == \
            len(pe.meter.charges)
        qids = [c.qid for c in pe.meter.charges]
        assert len(set(qids)) == len(qids)          # one line per query
        assert eng.summary()["energy"]["compute_j"] > 0

    def test_capped_replay_property(self, table, tiers):
        """Acceptance: the governed replay never exceeds budget over any
        window, and still reports attainment."""
        _, eng0, att0, _ = self.run_capped(table, tiers, None)
        demand_w = (eng0.summary()["energy"]["total_j"]
                    / eng0.seconds_total)
        for frac in (0.5, 0.8):
            _, eng, att, cap = self.run_capped(table, tiers,
                                               frac * demand_w)
            rep = cap.report(now=eng.clock())
            assert rep["max_window_w"] <= cap.budget_w * (1 + 1e-9), rep
            assert att is not None and 0.0 <= att <= 1.0
            assert att <= att0 + 1e-9       # the cap can only cost SLA
        s = eng.summary()
        assert s["power"]["budget_utilization"] <= 1 + 1e-9
        assert s["power"]["segments"] == s["served"]

    def test_power_infeasible_rejected_at_admission(self, table, tiers):
        """A deadline feasible at the bandwidth rate but not at the
        power-derated rate is rejected at submit."""
        pe = PlacementEngine.for_table(table, tiers, Policy.STATIC,
                                       chunk_rows=256,
                                       meter=EnergyMeter(tiers))
        clk = VirtualClock()
        q = Query(Pred("c00", "lt", 64), aggregates=("c01",))
        probe = QueryEngine(table, mode="xla_ref", tiered=pe, clock=clk)
        nbytes = sum(probe.chunk_accesses(q).values())
        bw_est = nbytes / probe.measured_bps
        e_query = tiers.energy_j(*_split(pe, probe, q))
        # budget so tight the query must stretch to ~10x its window
        cap = PowerCap(budget_w=e_query / (10 * bw_est),
                       window_s=bw_est)
        pe2 = PlacementEngine.for_table(table, tiers, Policy.STATIC,
                                        chunk_rows=256)
        eng = QueryEngine(table, mode="xla_ref", tiered=pe2,
                          clock=VirtualClock(), power_cap=cap)
        assert eng.submit(q, deadline=2 * bw_est) is None     # power-bound
        assert eng.submit(q, deadline=1e9) is not None        # just slow
        res = eng.run()[0]
        assert res.tier["throttle_s"] > 0
        assert cap.max_window_watts() <= cap.budget_w * (1 + 1e-9)
        assert res.met

    def test_power_cap_requires_tiered(self, table):
        with pytest.raises(ValueError, match="tiered"):
            QueryEngine(table, power_cap=PowerCap(1.0, 1.0),
                        clock=VirtualClock())

    def test_project_does_not_mutate_placement(self, table, tiers):
        pe = PlacementEngine.for_table(table, tiers, Policy.MEMCACHE,
                                       chunk_rows=256)
        chunks = {cid: int(pe.nbytes[i])
                  for cid, i in list(pe.index.items())[:6]}
        before = (pe.in_fast.copy(), pe.freq.copy(), pe.last_access.copy(),
                  pe._clock, len(pe.meter.charges))
        split = pe.project(chunks)
        assert split.total_bytes == sum(chunks.values())
        after = (pe.in_fast, pe.freq, pe.last_access, pe._clock,
                 len(pe.meter.charges))
        np.testing.assert_array_equal(before[0], after[0])
        np.testing.assert_array_equal(before[1], after[1])
        np.testing.assert_array_equal(before[2], after[2])
        assert before[3:] == after[3:]
        with pytest.raises(ValueError, match="unknown chunk"):
            pe.project({("nope", 0): 4})


def _split(pe, eng, q):
    acc = pe.project(eng.chunk_accesses(q))
    return acc.fast_bytes, acc.capacity_bytes


# --------------------------------------------------------------------------
# tco: $/query and the decision surface
# --------------------------------------------------------------------------
class TestTCO:
    def test_usd_per_query_terms(self):
        sheet = CostSheet(usd_per_kwh=0.10, amortize_s=1000.0)
        # capex 1000 over 1000 s at 1 s/query -> $1/query capex share
        assert usd_per_query(1000.0, 1.0, 0.0, sheet) == pytest.approx(1.0)
        # 3.6 MJ = 1 kWh -> $0.10
        assert usd_per_query(0.0, 1.0, 3.6e6, sheet) == pytest.approx(0.10)
        with pytest.raises(ValueError, match="response_time"):
            usd_per_query(1.0, 0.0, 1.0, sheet)
        with pytest.raises(ValueError, match="energy_j"):
            usd_per_query(1.0, 1.0, float("nan"), sheet)

    def test_cost_sheet_unknown_system(self):
        with pytest.raises(ValueError, match="no \\$/GiB price"):
            CostSheet().mem_usd("quantum-foam")
        # density variants inherit their base system's price
        assert CostSheet().mem_usd("die-stacked-x8density") == \
            CostSheet().mem_usd("die-stacked")

    def test_evaluate_system_matches_provisioning(self):
        c = evaluate_system(DIE_STACKED, WL, 0.010)
        d = provision_performance(DIE_STACKED, WL, 0.010)
        assert c["power_w"] == pytest.approx(d.power)
        assert c["response_time_s"] == pytest.approx(d.response_time)
        assert c["energy_per_query_j"] == pytest.approx(d.energy_per_query)
        assert c["meets_sla"]

    def test_die_stacking_wins_strict_slas(self):
        """Acceptance: datasheet inputs, <= 10 ms, generous power."""
        for sla in (0.005, 0.010):
            cell = cheapest_architecture(DB, BPQ, sla, 1e6)
            assert cell["winner"] == "die-stacked", cell

    def test_die_stacking_loses_power_at_relaxed_slas(self):
        """Acceptance: relaxed SLA, die-stacked is power-infeasible at a
        budget traditional meets comfortably — it loses on power, exactly
        the paper's 50x verdict."""
        cell = cheapest_architecture(DB, BPQ, 1.0, 20e3)
        by = {c["name"]: c for c in cell["candidates"]}
        assert not by["die-stacked"]["within_power"]
        assert by["traditional"]["feasible"]
        assert cell["winner"] == "traditional"

    def test_crossover_consistent_with_power_crossover_sla(self):
        """The surface's candidate powers flip exactly where the paper's
        analytical crossover says they do (~60 ms)."""
        t_star = power_crossover_sla(TRADITIONAL, DIE_STACKED, WL)
        assert t_star is not None
        for sla, die_wins_power in ((t_star / 3, True), (t_star * 3, False)):
            cell = cheapest_architecture(DB, BPQ, sla, 1e9)
            by = {c["name"]: c for c in cell["candidates"]}
            assert (by["die-stacked"]["power_w"]
                    < by["traditional"]["power_w"]) == die_wins_power, sla

    def test_nothing_feasible_is_honest(self):
        cell = cheapest_architecture(DB, BPQ, 0.010, 1e3)   # 1 kW: nobody
        assert cell["winner"] is None
        assert cell["usd_per_query"] is None

    def test_tiered_candidate_exploits_skew(self):
        """At a strict SLA and high skew, the two-tier node undercuts the
        pure die-stacked cluster (cold bytes live in cheap DDR)."""
        cell = cheapest_architecture(DB, BPQ, 0.010, 1e6, skew=1.1)
        by = {c["name"]: c for c in cell["candidates"]}
        assert by["tiered"]["feasible"]
        assert by["tiered"]["usd_per_query"] <= \
            by["die-stacked"]["usd_per_query"] * (1 + 1e-9)
        t = evaluate_tiered(DB, BPQ, 0.010, 1.1)
        assert 0 < t["fast_fraction"] <= 1.0
        assert t["response_time_s"] <= 0.010 * (1 + 1e-9)

    def test_tiered_rejects_mismeasured_fast_rate(self):
        """A fast rate above the datasheet Eq. 4 roofline (broken tune
        cache) must not price a tiered candidate at an unattainable
        operating point — every row fails the cross-check, so there is
        no candidate at all."""
        assert evaluate_tiered(DB, BPQ, 0.010, 1.1, fast_gbps=500.0) is None
        cell = cheapest_architecture(DB, BPQ, 0.010, 1e6, skew=1.1,
                                     fast_gbps=500.0)
        assert all(c["name"] != "tiered" for c in cell["candidates"])

    def test_decision_surface_grid(self):
        surf = decision_surface(DB, BPQ, slas=(0.010, 1.0),
                                skews=(None, 1.1),
                                power_budgets_w=(50e3, 1e6))
        assert len(surf["cells"]) == 8
        for cell in surf["cells"]:
            names = [c["name"] for c in cell["candidates"]]
            assert names[:3] == ["traditional", "big-memory", "die-stacked"]
            assert cell["winner"] is None or cell["winner"] in names

    def test_guards_degenerate_inputs(self):
        with pytest.raises(ValueError, match="positive"):
            cheapest_architecture(0, 1, 0.01, 1e6)
        with pytest.raises(ValueError, match="sla_s"):
            cheapest_architecture(1, 1, float("nan"), 1e6)
        with pytest.raises(ValueError, match="power_budget_w"):
            cheapest_architecture(1, 1, 0.01, -5.0)

    def test_advise_cost_measured_repricing(self):
        cell = advise_cost(DB, BPQ, 0.010, 1e6, measured_energy_j=3.6e6,
                           measured_latency_s=0.012)
        assert cell["usd_per_query_measured"] > 0
        with pytest.raises(ValueError, match="both"):
            advise_cost(DB, BPQ, 0.010, 1e6, measured_energy_j=1.0)


# --------------------------------------------------------------------------
# satellite: power_crossover_sla cross-checked against fig4 + TCO
# --------------------------------------------------------------------------
class TestPowerCrossoverCrossChecks:
    def test_performance_powers_meet_at_crossover(self):
        t_star = power_crossover_sla(TRADITIONAL, DIE_STACKED, WL)
        p_trad = provision_performance(TRADITIONAL, WL, t_star).power
        p_die = provision_performance(DIE_STACKED, WL, t_star).power
        # the scan interpolates in log-t between 4000 samples; the power
        # curves are steppy (ceil of chips), so "equal" is a few percent
        assert p_trad == pytest.approx(p_die, rel=0.05)

    def test_inverse_consistency_with_power_provisioning(self):
        """fig4's machinery approximately inverts the crossover: a
        cluster power-provisioned at the crossover power lands near the
        crossover SLA. Not exact by design — provision_power populates
        blades at full cores (the paper's §5.2 assumption), so at relaxed
        SLAs it buys more compute than performance provisioning would
        (traditional lands ~1.3x slower, die-stacked ~0.9x) — but the two
        regimes must agree at the shared operating point within the
        blade-quantization band."""
        t_star = power_crossover_sla(TRADITIONAL, DIE_STACKED, WL)
        for sys_ in (TRADITIONAL, DIE_STACKED):
            p = provision_performance(sys_, WL, t_star).power
            rt = provision_power(sys_, WL, p).response_time
            assert t_star / 2 <= rt <= t_star * 2, (sys_.name, rt, t_star)

    def test_fig4_bench_rows_match_provision_power(self):
        """The fig4 benchmark's derived strings are the model's numbers,
        not a drifted copy."""
        import benchmarks.fig4_power_provisioning as fig4
        for name, _, derived in fig4.rows():
            budget = float(re.search(r"/(\d+)kW/", name).group(1)) * 1e3
            sys_name = name.rsplit("/", 1)[1]
            sys_ = {s.name: s for s in (TRADITIONAL, DIE_STACKED)}.get(
                sys_name)
            if sys_ is None:
                continue
            d = provision_power(sys_, fig4.WL, budget)
            rt_ms = float(re.search(r"rt=([\d.]+)ms", derived).group(1))
            pw_kw = float(re.search(r"power=([\d.]+)kW", derived).group(1))
            assert rt_ms == pytest.approx(d.response_time * 1e3, abs=0.05)
            assert pw_kw == pytest.approx(d.power / 1e3, abs=0.05)

    def test_tco_power_ordering_flips_with_crossover(self):
        """The TCO model's energy-per-query ordering at the crossover's
        two sides matches the analytical model's power ordering."""
        t_star = power_crossover_sla(TRADITIONAL, DIE_STACKED, WL)
        strict = {c["name"]: c for c in cheapest_architecture(
            DB, BPQ, t_star / 3, 1e9)["candidates"]}
        relaxed = {c["name"]: c for c in cheapest_architecture(
            DB, BPQ, t_star * 3, 1e9)["candidates"]}
        assert strict["die-stacked"]["energy_per_query_j"] < \
            strict["traditional"]["energy_per_query_j"]
        assert relaxed["die-stacked"]["energy_per_query_j"] > \
            relaxed["traditional"]["energy_per_query_j"]


# --------------------------------------------------------------------------
# bench wiring: run.py --only energy appends to BENCH_energy.json
# --------------------------------------------------------------------------
def test_energy_bench_appends_record(tmp_path, monkeypatch, capsys):
    import benchmarks.energy_bench as energy_bench
    import benchmarks.run as bench_run
    monkeypatch.setenv("REPRO_ENERGY_BENCH_QUICK", "1")
    monkeypatch.setattr(energy_bench, "BENCH_PATH", tmp_path / "B.json")
    # "energy_bench", not "energy": the substring filter would also pull
    # in benchmarks.fig6_energy
    bench_run.main(["--only", "energy_bench", "--json"])
    records = json.loads(capsys.readouterr().out)
    assert any(r["name"].startswith("energy/") for r in records)
    hist = json.loads((tmp_path / "B.json").read_text())
    assert len(hist) == 1
    rec = hist[0]
    assert rec["replay"]["capped"]["budget_utilization"] <= 1 + 1e-9
    assert rec["replay"]["by_tenant"]
    assert all(w is None or isinstance(w, str)
               for w in rec["surface"]["winners"].values())
    assert math.isfinite(rec["replay"]["demand_w"])
