"""Grouped aggregation & hash join tests (ISSUE 8).

Parity contract: GroupBy/HashJoin produce results bit-identical to the
numpy oracle under PALLAS, XLA_REF, and AUTO — on plain tables, over the
compressed store (all three per-chunk strategies: fused RLE, dense
accumulator planes, host sort/hash fallback), and through the tiered
engine. The fused RLE path must stay ONE batched launch with no scatter
and no fallback; grouped queries must charge physical bytes into the
tier and energy ledgers like any scan.
"""
import numpy as np
import pytest

from repro.db.columnar import BitPackedColumn, Table
from repro.kernels import dispatch
from repro.kernels.group_aggregate import ops as gops
from repro.query import GroupBy, HashJoin, Pred, QueryEngine
from repro.query import relational
from repro.query.plan import And
from repro.serve.sla import VirtualClock
from repro.store import EncodedTable
from repro.store.exec import execute_grouped_encoded
from repro.tier.placement import PlacementEngine, Policy
from repro.tier.tiers import paper_tiers

MODES = ("pallas", "xla_ref", "auto")
N_ROWS = 6001          # ragged vs every codes-per-word and the chunking
CHUNK_ROWS = 1024


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(3)
    t = Table("t")
    t.add(BitPackedColumn.from_values(          # sorted low-card -> RLE
        "r", np.sort(rng.integers(0, 8, N_ROWS)), 8))
    t.add(BitPackedColumn.from_values(          # clustered -> FOR
        "f", 40 + rng.integers(0, 8, N_ROWS), 8))
    t.add(BitPackedColumn.from_values(          # 16-bit clustered -> FOR
        "w", 9000 + rng.integers(0, 100, N_ROWS), 16))
    t.add(BitPackedColumn.from_values(          # uniform -> plain
        "u", rng.integers(0, 128, N_ROWS), 8))
    return t


@pytest.fixture(scope="module")
def encoded(table):
    return EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)


@pytest.fixture(scope="module")
def dim():
    d = Table("dim")
    d.add(BitPackedColumn.from_values("r", np.array([1, 3, 5, 99]), 8))
    d.add(BitPackedColumn.from_values("u", np.array([2, 7, 50, 90]), 8))
    return d


def _np_grouped(table, key, aggs, sel):
    """Independent numpy ground truth (no repro.query.relational code)."""
    cols = {n: c.decode().astype(np.int64)
            for n, c in table.columns.items()}
    k = cols[key][sel]
    groups = {}
    for kv in np.unique(k):
        m = sel & (cols[key] == kv)
        groups[int(kv)] = {
            "count": int(m.sum()),
            "sums": {a: int(cols[a][m].sum()) for a in sorted(aggs)}}
    return {"groups": groups, "count": int(sel.sum())}


# --------------------------------------------------------------------------
# bind / error paths
# --------------------------------------------------------------------------

def test_groupby_unknown_column_raises(table):
    with pytest.raises(ValueError, match="zz"):
        relational.execute_grouped(GroupBy("zz"), table)
    with pytest.raises(ValueError, match="zz"):
        relational.execute_grouped(GroupBy("r", ("zz",)), table)
    with pytest.raises(ValueError, match="zz"):
        relational.execute_grouped(
            GroupBy("r", where=Pred("zz", "lt", 3)), table)


def test_groupby_aggregate_over_key_raises():
    with pytest.raises(ValueError, match="group key"):
        GroupBy("r", ("r",))


def test_groupby_multi_key_raises():
    with pytest.raises(ValueError, match="one group-key"):
        GroupBy(("r", "u"))


def test_join_build_side_missing_column_raises(table):
    with pytest.raises(ValueError, match="no column"):
        HashJoin(table, "r", "zz")


def test_join_key_width_mismatch_names_both_sides(table, dim):
    # probe "w" is 16-bit, build "r" is 8-bit
    j = HashJoin(dim, "w", "r")
    with pytest.raises(ValueError) as e:
        relational.bind_check(j, table.columns)
    msg = str(e.value)
    assert "16-bit" in msg and "8-bit" in msg
    assert "'w'" in msg and "'r'" in msg


def test_engine_submit_runs_bind_checks(table, dim):
    eng = QueryEngine(table)
    with pytest.raises(ValueError, match="zz"):
        eng.submit(GroupBy("zz"))
    with pytest.raises(ValueError, match="width mismatch"):
        eng.submit(HashJoin(dim, "w", "r"))


# --------------------------------------------------------------------------
# plain-table parity (dense strategy + wide-key fallback)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_groupby_plain_matches_numpy(table, mode):
    q = GroupBy("r", ("u", "f"), where=Pred("u", "lt", 90))
    cols = {n: c.decode().astype(np.int64)
            for n, c in table.columns.items()}
    want = _np_grouped(table, "r", ("u", "f"), cols["u"] < 90)
    assert relational.execute_grouped(q, table, mode=mode) == want
    assert relational.execute_grouped_oracle(q, table) == want


@pytest.mark.parametrize("mode", MODES)
def test_groupby_mixed_width_predicate(table, mode):
    # 8-bit key grouped under a 16-bit predicate: the unpacked planes
    # have different padded lengths and must land on one row axis
    q = GroupBy("r", ("u",), where=And((Pred("w", "ge", 9030),
                                        Pred("f", "lt", 45))))
    assert relational.execute_grouped(q, table, mode=mode) \
        == relational.execute_grouped_oracle(q, table)


@pytest.mark.parametrize("mode", MODES)
def test_hash_join_semantics(table, dim, mode):
    # probe keys restricted to the build side's distinct keys; key 99
    # never occurs in the fact table and must not appear as a group
    j = HashJoin(dim, "r", "r", aggs=("u",), where=Pred("f", "lt", 46))
    got = relational.execute_grouped(j, table, mode=mode)
    cols = {n: c.decode().astype(np.int64)
            for n, c in table.columns.items()}
    sel = (cols["f"] < 46) & np.isin(cols["r"], [1, 3, 5, 99])
    assert got == _np_grouped(table, "r", ("u",), sel)
    assert set(got["groups"]) <= {1, 3, 5}


def test_count_only_histogram(table):
    got = relational.execute_grouped(GroupBy("r"), table)
    r = table.columns["r"].decode()
    assert got["count"] == N_ROWS
    for k, g in got["groups"].items():
        assert g["count"] == int((r == k).sum()) and g["sums"] == {}


def test_empty_selection_and_zero_rows(table):
    q = GroupBy("r", ("u",), where=Pred("u", "gt", 127))
    assert relational.execute_grouped(q, table) \
        == relational.empty_result()
    empty = Table("e")
    empty.add(BitPackedColumn.from_values("r", np.zeros(0, np.int64), 8))
    assert relational.execute_grouped(GroupBy("r"), empty) \
        == relational.empty_result()


def test_wide_key_takes_fallback_and_matches(table):
    # 16-bit key spans ~100 codes > nothing, but force the cliff: shrink
    # the dense cutoff, the documented strategy knob
    q = GroupBy("w", ("u",))
    want = relational.execute_grouped_oracle(q, table)
    saved = relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS
    try:
        relational.DENSE_MAX_GROUPS = gops.DENSE_MAX_GROUPS = 4
        before = dict(dispatch.launch_counts())
        got = relational.execute_grouped(q, table)
    finally:
        relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS = saved
    delta = {k: v - before.get(k, 0)
             for k, v in dispatch.launch_counts().items()}
    assert delta.get("group_aggregate_fallback", 0) >= 1
    assert delta.get("group_aggregate", 0) == 0
    assert got == want == relational.execute_grouped(q, table)


# --------------------------------------------------------------------------
# encoded store: the three per-chunk strategies
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_encoded_grouped_parity(table, encoded, mode):
    for q in (GroupBy("r", ("u", "f")),
              GroupBy("f", ("w",), where=Pred("u", "lt", 64)),
              GroupBy("r"),                         # count-only: RLE path
              GroupBy("r", where=Pred("r", "le", 4))):
        assert execute_grouped_encoded(q, encoded, mode=mode) \
            == relational.execute_grouped_oracle(q, table), q


def test_rle_pregrouped_is_one_launch_no_scatter(table, encoded):
    """The ISSUE's launch-observability acceptance: a count-only GroupBy
    on the RLE key takes ONE batched run-accumulation launch — no dense
    plane, no host fallback."""
    q = GroupBy("r", where=Pred("r", "lt", 6))
    execute_grouped_encoded(q, encoded, mode="xla_ref")     # warm
    before = dict(dispatch.launch_counts())
    got = execute_grouped_encoded(q, encoded, mode="xla_ref")
    delta = {k: v - before.get(k, 0)
             for k, v in dispatch.launch_counts().items()
             if v != before.get(k, 0)}
    assert delta == {"group_aggregate_rle": 1}, delta
    assert got == relational.execute_grouped_oracle(q, table)


def test_encoded_forced_fallback_parity(table, encoded):
    q = GroupBy("r", ("u",))
    want = relational.execute_grouped_oracle(q, table)
    saved = relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS
    try:
        relational.DENSE_MAX_GROUPS = gops.DENSE_MAX_GROUPS = 0
        before = dict(dispatch.launch_counts())
        got = execute_grouped_encoded(q, encoded, mode="xla_ref")
    finally:
        relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS = saved
    assert got == want
    delta = {k: v - before.get(k, 0)
             for k, v in dispatch.launch_counts().items()}
    assert delta.get("group_aggregate_fallback", 0) == encoded.n_chunks


@pytest.mark.parametrize("mode", ("pallas", "xla_ref"))
def test_encoded_join_parity(table, encoded, dim, mode):
    j = HashJoin(dim, "u", "u", aggs=("f",), where=Pred("r", "lt", 7))
    assert execute_grouped_encoded(j, encoded, mode=mode) \
        == relational.execute_grouped_oracle(j, table)


# --------------------------------------------------------------------------
# engine integration: routing + tier/energy accounting
# --------------------------------------------------------------------------

def test_engine_grouped_result_shape(table, dim):
    eng = QueryEngine(table)
    q = GroupBy("r", ("u",), where=Pred("u", "lt", 90))
    eng.submit(q)
    (r,) = eng.run()
    want = relational.execute_grouped_oracle(q, table)
    assert r.aggregates == want and r.count == want["count"]
    assert r.bytes_scanned == eng.bytes_scanned(q) > 0
    eng.submit(HashJoin(dim, "r", "r", aggs=("u",)))
    (r,) = eng.run()
    assert r.aggregates == relational.execute_grouped_oracle(
        HashJoin(dim, "r", "r", aggs=("u",)), table)


def test_grouped_charges_tier_and_energy(table, encoded):
    """A grouped query streams physical (compressed) bytes through the
    placement engine and lands on the energy ledger, same as a scan."""
    clock = VirtualClock()
    pe = PlacementEngine.for_table(
        encoded, paper_tiers(max(1, encoded.nbytes // 2)), Policy.CACHE,
        chunk_rows=CHUNK_ROWS)
    eng = QueryEngine(encoded, clock=clock, tiered=pe)
    q = GroupBy("r", ("u",), where=Pred("f", "lt", 45))
    eng.submit(q, deadline=clock() + 100.0)
    (r,) = eng.run()
    assert r.aggregates == relational.execute_grouped_oracle(q, table)
    assert r.tier is not None and r.tier["service_s"] > 0
    assert r.tier["energy_j"] > 0
    s = eng.summary()
    assert s["bytes_scanned"] == r.bytes_scanned > 0
    # physical bytes: the compressed footprint of r+u+f, not the logical
    assert r.bytes_scanned < r.logical_bytes
    assert s["energy"]["total_j"] > 0
