"""Child process for multi-device tests: 8 host devices via XLA_FLAGS.

Run by tests/test_dist_multidevice.py (device count locks at first jax
import, so these cannot run inside the main pytest process).
Each check prints 'OK <name>' on success; exits nonzero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.compression import (compressed_psum_pod,
                                    error_feedback_compress)
from repro.dist.pipeline_parallel import bubble_fraction, gpipe
from repro.launch.mesh import make_mesh


def check_pipeline():
    mesh = make_mesh((4,), ("pod",))
    s, m, d = 4, 8, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (s, d, d)) / np.sqrt(d)
    xs = jax.random.normal(key, (m, 2, d))

    def stage(w, x):
        return jnp.tanh(x @ w)

    got = gpipe(stage, ws, xs, mesh=mesh, axis="pod")

    want = xs
    for i in range(s):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(m, s) - 3 / 11) < 1e-9
    print("OK pipeline")


def check_pipeline_lowers_on_2d_mesh():
    """PP on 'pod' composes with DP on 'data' (lowering check)."""
    mesh = make_mesh((4, 2), ("pod", "data"))
    s, m, d = 4, 4, 8
    ws = jax.ShapeDtypeStruct((s, d, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((m, 4, d), jnp.float32)

    def stage(w, x):
        return jnp.tanh(x @ w)

    def run(ws, xs):
        return gpipe(stage, ws, xs, mesh=mesh, axis="pod")

    jax.jit(run,
            in_shardings=(NamedSharding(mesh, P("pod")),
                          NamedSharding(mesh, P(None, "data"))),
            ).lower(ws, xs).compile()
    print("OK pipeline_2d_lowering")


def check_compression():
    mesh = make_mesh((4, 2), ("pod", "data"))
    key = jax.random.PRNGKey(1)
    g = {"a": jax.random.normal(key, (64, 32)),
         "b": jax.random.normal(key, (8,)) * 10}
    # replicate across devices
    g = jax.tree.map(lambda x: jax.device_put(
        x, NamedSharding(mesh, P())), g)
    got = compressed_psum_pod(g, mesh, axis="pod")
    want = jax.tree.map(lambda x: 4.0 * x, g)   # psum of 4 identical shards
    for k in g:
        a, b = np.asarray(got[k]), np.asarray(want[k])
        rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
        assert rel < 2e-2, (k, rel)   # int8 quantization error bound
    print("OK compression")


def check_error_feedback():
    key = jax.random.PRNGKey(2)
    g = {"w": jax.random.normal(key, (128,))}
    res = None
    acc_sent = jnp.zeros((128,))
    acc_true = jnp.zeros((128,))
    for _ in range(50):
        sent, res = error_feedback_compress(g, res)
        acc_sent += sent["w"]
        acc_true += g["w"]
    # error feedback: accumulated sent converges to accumulated true
    rel = float(jnp.max(jnp.abs(acc_sent - acc_true))
                / jnp.max(jnp.abs(acc_true)))
    assert rel < 1e-2, rel
    print("OK error_feedback")


def check_sharded_train_step():
    """End-to-end: real train step on a (2,4) production-shaped mesh."""
    from repro.configs import SHAPES, get_config
    from repro.configs.base import ShapeSpec
    from repro.data import DataConfig, SyntheticLM, make_global_batch
    from repro.launch import specs
    import dataclasses

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("internlm2-1.8b").reduced(num_layers=2, d_model=64,
                                               num_heads=4, num_kv_heads=2,
                                               dtype="float32")
    shape = ShapeSpec("tiny", "train", seq_len=32, global_batch=4)
    jitted, abstract = specs.build_train(cfg, shape, mesh)
    # materialize real state + batch with the same shardings
    from repro.train import optim, step as step_lib
    state, state_axes = step_lib.init_state(jax.random.PRNGKey(0), cfg,
                                            optim.AdamWConfig())
    from repro.dist.sharding import sharding_tree
    rules = specs.rules_for(cfg, shape)
    st_sh = sharding_tree(state, state_axes, mesh, rules)
    state = jax.tree.map(jax.device_put, state, st_sh)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=4))
    batch = make_global_batch(ds.batch(0), mesh,
                              {"inputs": P("data"), "labels": P("data")})
    losses = []
    for _ in range(3):
        state, metrics = jitted(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), losses
    print("OK sharded_train_step", losses)


def check_elastic_rescale():
    """Train on a (2,4) mesh, checkpoint, restore onto an (8,1) mesh and
    continue — the final state must equal an uninterrupted run (the mesh
    is a deployment detail, not part of the math)."""
    import tempfile

    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.data import DataConfig, SyntheticLM, make_global_batch
    from repro.dist.sharding import sharding_tree
    from repro.launch import specs
    from repro.train import optim, step as step_lib

    cfg = get_config("internlm2-1.8b").reduced(num_layers=2, d_model=64,
                                               num_heads=4, num_kv_heads=2,
                                               dtype="float32")
    shape = ShapeSpec("tiny", "train", seq_len=32, global_batch=8)
    opt_cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8))

    def setup(mesh):
        jitted, _ = specs.build_train(cfg, shape, mesh, opt_cfg=opt_cfg)
        state, axes = step_lib.init_state(jax.random.PRNGKey(0), cfg,
                                          opt_cfg)
        sh = sharding_tree(state, axes, mesh, specs.rules_for(cfg, shape))
        return jitted, state, sh

    def run(jitted, state, mesh, steps_from, steps_to):
        for s in range(steps_from, steps_to):
            batch = make_global_batch(ds.batch(s), mesh,
                                      {"inputs": P("data"),
                                       "labels": P("data")})
            state, _ = jitted(state, batch)
        return state

    # uninterrupted reference on mesh A
    mesh_a = make_mesh((2, 4), ("data", "model"))
    jit_a, state0, sh_a = setup(mesh_a)
    state0 = jax.tree.map(jax.device_put, state0, sh_a)
    ref = run(jit_a, state0, mesh_a, 0, 4)

    # 2 steps on mesh A -> checkpoint -> restore on mesh B -> 2 more
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        jit_a2, s0, _ = setup(mesh_a)
        s0 = jax.tree.map(jax.device_put, s0, sh_a)
        mid = run(jit_a2, s0, mesh_a, 0, 2)
        mgr.save(2, mid)

        mesh_b = make_mesh((8, 1), ("data", "model"))
        jit_b, skeleton, sh_b = setup(mesh_b)
        restored, meta = mgr.restore(skeleton, shardings=sh_b)
        assert meta["step"] == 2
        final = run(jit_b, restored, mesh_b, 2, 4)

    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5), ref, final)
    print("OK elastic_rescale")


def check_sharded_query_engine():
    """8-shard scan-aggregate must match the single-device oracle
    bit-exactly: AND/OR, mixed widths, fused path, non-divisible rows."""
    from repro.db import Table
    from repro.query import Pred, Query, QueryEngine, ShardedTable

    table = Table.synthetic("t", 100_001, {"a": 8, "b": 8, "w": 16, "x": 4},
                            seed=11)
    mesh = make_mesh((8,), ("data",))
    st = ShardedTable.shard(table, mesh)
    assert st.n_shards == 8
    queries = [
        Query(Pred("a", "lt", 64), aggregates=("b",)),          # fused
        Query(Pred("a", "lt", 50) & Pred("w", "ge", 9000),      # mixed AND
              aggregates=("w", "b")),
        Query(Pred("x", "eq", 3) | Pred("w", "lt", 500),        # mixed OR
              aggregates=("a",)),
    ]
    single = QueryEngine(table, mode="auto")
    sharded = QueryEngine(st, mode="auto")
    for q in queries:
        single.submit(q)
        sharded.submit(q)
        want = single.run()[0]
        got = sharded.run()[0]
        assert got.aggregates == want.aggregates, (q, got.aggregates,
                                                   want.aggregates)
        assert got.count == want.count
    assert sharded.summary()["measured_gbps"] > 0
    mc = sharded.model_check()
    assert mc["chips"] == 8 and mc["measured_gbps"] > 0
    print("OK sharded_query_engine")


def check_compressed_store():
    """8-shard scans over the compressed delta view must match the plain
    single-device oracle bit-exactly — every encoding, fused and general
    shapes, empty selections, non-divisible rows."""
    import numpy as np

    from repro.db.columnar import BitPackedColumn, Table
    from repro.query import Pred, Query, QueryEngine
    from repro.store import EncodedTable, ShardedEncodedTable

    rng = np.random.default_rng(13)
    n = 100_001
    table = Table("t")
    table.add(BitPackedColumn.from_values(
        "r", np.sort(rng.integers(0, 8, n)), 8))             # RLE
    table.add(BitPackedColumn.from_values(
        "f", 40 + rng.integers(0, 8, n), 8))                 # FOR
    table.add(BitPackedColumn.from_values(
        "w", 9000 + rng.integers(0, 100, n), 16))            # FOR 16->8
    table.add(BitPackedColumn.from_values(
        "u", rng.integers(0, 128, n), 8))                    # plain
    encoded = EncodedTable.from_table(table, chunk_rows=4096)
    mesh = make_mesh((8,), ("data",))
    st = ShardedEncodedTable.shard(encoded, mesh)
    assert st.n_shards == 8
    assert st.nbytes < sum(4 * int(c.words.size)
                           for c in table.columns.values()), \
        "delta view should be smaller than the plain device footprint"
    queries = [
        Query(Pred("r", "lt", 4), aggregates=("r",)),        # RLE col
        Query(Pred("f", "ge", 44), aggregates=("w",)),       # FOR x FOR
        Query(Pred("f", "ge", 42) & Pred("w", "lt", 9080),   # mixed AND
              aggregates=("w", "u")),
        Query(Pred("f", "lt", 40), aggregates=("f",)),       # empty
        Query(Pred("w", "ge", 0), aggregates=("w",)),        # all-match
    ]
    single = QueryEngine(table, mode="auto")
    sharded = QueryEngine(st, mode="auto")
    for q in queries:
        single.submit(q)
        sharded.submit(q)
        want = single.run()[0]
        got = sharded.run()[0]
        assert got.aggregates == want.aggregates, (q, got.aggregates,
                                                   want.aggregates)
        assert got.count == want.count
    assert sharded.summary()["measured_gbps"] > 0
    print("OK compressed_store")


def check_resilience():
    """Degraded-mode shard failover on 8 shards: any subset of lost
    shards re-executes from the host copy bit-exactly (plain + encoded),
    all-shards-lost raises typed, and the engine-level chaos path keeps
    every answer exact while charging recovery traffic."""
    from repro.db import Table
    from repro.query import Pred, Query, QueryEngine, ShardedTable
    from repro.resilience import (ChaosHarness, DegradedResultError,
                                  FaultSpec, execute_degraded)
    from repro.serve.sla import VirtualClock
    from repro.store import EncodedTable, ShardedEncodedTable
    from repro.tier.placement import PlacementEngine, Policy
    from repro.tier.tiers import paper_tiers

    table = Table.synthetic("t", 100_001, {"a": 8, "b": 8, "w": 16},
                            seed=11)
    mesh = make_mesh((8,), ("data",))
    st = ShardedTable.shard(table, mesh)
    se = ShardedEncodedTable.shard(EncodedTable.from_table(table), mesh)
    queries = [
        Query(Pred("a", "lt", 64), aggregates=("b",)),           # fused
        Query(Pred("a", "lt", 50) & Pred("w", "ge", 9000),       # mixed AND
              aggregates=("w", "b")),
        Query(Pred("a", "gt", 127), aggregates=("b",)),          # empty sel
    ]
    for sharded in (st, se):
        for q in queries:
            want = sharded.execute(q.plan(), q.aggregates)
            for lost in ([0], [7], [3, 5], list(range(7))):
                got, rec_b = execute_degraded(sharded, q.plan(),
                                              q.aggregates, lost)
                assert got == want, (lost, got, want)
                assert rec_b > 0
            try:
                execute_degraded(sharded, q.plan(), q.aggregates,
                                 list(range(8)))
                raise AssertionError("all-shards-lost did not raise")
            except DegradedResultError:
                pass

    # engine-level: seeded shard dropouts, every answer exact, recovery
    # bytes on the ledger; same seed -> same resilience summary
    def chaos_run():
        clock = VirtualClock()
        pe = PlacementEngine.for_table(st, paper_tiers(st.nbytes // 2),
                                       Policy.CACHE, chunk_rows=4096)
        eng = QueryEngine(st, mode="auto", clock=clock, tiered=pe,
                          chaos=ChaosHarness(
                              FaultSpec(seed=5, shard_loss_rate=0.5)))
        want = st.execute(queries[0].plan(), queries[0].aggregates)
        for _ in range(10):
            eng.submit(queries[0], deadline=clock() + 10.0)
            r = eng.run()[0]
            assert r.aggregates == want and not r.degraded
        return eng.summary()
    s1, s2 = chaos_run(), chaos_run()
    assert s1["resilience"] == s2["resilience"]
    assert s1["resilience"]["shard_losses"] > 0
    assert s1["resilience"]["shard_recoveries"] == \
        s1["resilience"]["shard_losses"]
    assert s1["tier"]["recovery_bytes"] > 0
    print("OK resilience")


def check_relational():
    """8-shard GroupBy/HashJoin must match the single-device numpy oracle
    bit-exactly — plain and compressed delta views, the build side
    broadcast to every shard, and degraded re-execution for any
    lost-shard subset (all-lost raises typed)."""
    from repro.db.columnar import BitPackedColumn, Table
    from repro.query import GroupBy, HashJoin, Pred, relational
    from repro.query.sharded import ShardedTable
    from repro.resilience import DegradedResultError
    from repro.resilience.recover import execute_grouped_degraded
    from repro.store import EncodedTable, ShardedEncodedTable

    rng = np.random.default_rng(17)
    n = 100_001
    table = Table("t")
    table.add(BitPackedColumn.from_values(
        "r", np.sort(rng.integers(0, 8, n)), 8))             # RLE
    table.add(BitPackedColumn.from_values(
        "f", 40 + rng.integers(0, 8, n), 8))                 # FOR
    table.add(BitPackedColumn.from_values(
        "w", 9000 + rng.integers(0, 100, n), 16))            # FOR 16-bit
    table.add(BitPackedColumn.from_values(
        "u", rng.integers(0, 128, n), 8))                    # plain
    dim = Table("dim")
    dim.add(BitPackedColumn.from_values(
        "u", np.array([2, 7, 50, 90, 127]), 8))
    mesh = make_mesh((8,), ("data",))
    st = ShardedTable.shard(table, mesh)
    se = ShardedEncodedTable.shard(
        EncodedTable.from_table(table, chunk_rows=4096), mesh)
    queries = [
        GroupBy("r", ("u", "w")),                            # multi-agg
        GroupBy("f", ("w",), where=Pred("u", "lt", 64)),     # filtered
        GroupBy("r", where=Pred("r", "lt", 5)),              # count-only
        HashJoin(dim, "u", "u", aggs=("f",),                 # join clip
                 where=Pred("r", "lt", 7)),
        GroupBy("u", ("r",), where=Pred("u", "gt", 127)),    # empty sel
    ]
    for q in queries:
        want = relational.execute_grouped_oracle(q, table)
        for sharded in (st, se):
            got = sharded.execute_grouped(q)
            assert got == want, (q, got["count"], want["count"])
            for lost in ([0], [3, 5], list(range(7))):
                d, rec_b = execute_grouped_degraded(sharded, q, lost)
                assert d == want, (q, lost)
                assert rec_b > 0
            try:
                execute_grouped_degraded(sharded, q, list(range(8)))
                raise AssertionError("all-shards-lost did not raise")
            except DegradedResultError:
                pass
    print("OK relational")


def check_serve_step_sharded():
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch import specs

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("mixtral-8x22b").reduced(num_layers=2, dtype="float32")
    shape = ShapeSpec("tinydec", "decode", seq_len=64, global_batch=4)
    jitted, abstract = specs.build_serve(cfg, shape, mesh)
    jitted.lower(*abstract).compile()
    print("OK serve_step_sharded_lowering")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    checks = {
        "pipeline": check_pipeline,
        "pipeline2d": check_pipeline_lowers_on_2d_mesh,
        "compression": check_compression,
        "ef": check_error_feedback,
        "train": check_sharded_train_step,
        "serve": check_serve_step_sharded,
        "elastic": check_elastic_rescale,
        "query": check_sharded_query_engine,
        "store": check_compressed_store,
        "resilience": check_resilience,
        "relational": check_relational,
    }
    if which == "all":
        for fn in checks.values():
            fn()
    else:
        checks[which]()
    print("CHILD_DONE")
