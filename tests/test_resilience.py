"""Resilience tests: seeded chaos replays bit-identically; every faulted
query is bit-exact vs the numpy oracle or fails with a typed
DegradedResultError — never a wrapped or partial sum.

Multi-shard degraded failover runs in tests/multidevice_child.py
("resilience"); this file covers the single-device paths: determinism of
the fault stream, checksummed chunks (detect / quarantine / repair),
retry accounting (no double-charge), circuit-breaker demotion, admission
inflation, and the empty/zero-row identities on PALLAS and XLA_REF.
"""
import math

import numpy as np
import pytest

from repro.db import Table
from repro.launch.mesh import make_mesh
from repro.query import Pred, Query, QueryEngine, ShardedTable
from repro.query.engine import QueryResult
from repro.resilience import (ChaosHarness, ChunkCorruptionError,
                              ChunkGuard, CircuitBreaker,
                              DegradedResultError, FaultInjector,
                              FaultSpec, RetryPolicy, execute_degraded)
from repro.serve.sla import VirtualClock
from repro.store import EncodedTable
from repro.store.exec import execute_encoded, identity_ints
from repro.tier.placement import PlacementEngine, Policy
from repro.tier.tiers import paper_tiers

N_ROWS = 10_001
SPEC = {"a": 8, "b": 8}


@pytest.fixture(scope="module")
def table():
    return Table.synthetic("t", N_ROWS, SPEC, seed=3)


@pytest.fixture()
def query():
    return Query(Pred("a", "lt", 50), aggregates=("b",))


def make_engine(table, spec, *, recover=True, retry=None, breaker=None,
                guard=None, policy=Policy.CACHE, chunk_rows=2048,
                fast_fraction=0.5):
    clock = VirtualClock()
    pe = PlacementEngine.for_table(
        table, paper_tiers(max(1, int(table.nbytes * fast_fraction))),
        policy, chunk_rows=chunk_rows)
    chaos = ChaosHarness(spec, recover=recover, retry=retry,
                         breaker=breaker, guard=guard)
    return QueryEngine(table, clock=clock, tiered=pe, chaos=chaos), clock


def run_n(eng, clock, query, n, deadline_s=10.0):
    out = []
    for _ in range(n):
        eng.submit(query, deadline=clock() + deadline_s)
        out.extend(eng.run())
    return out


class TestFaultInjector:
    def test_draws_commute_and_replay(self):
        inj = FaultInjector(FaultSpec(seed=9, stall_rate=0.5,
                                      corrupt_rate=0.3,
                                      shard_loss_rate=0.4))
        events = [(q, ("col", c), a) for q in range(5) for c in range(4)
                  for a in range(3)]
        fwd = [inj.stalled(*e) for e in events]
        rev = [inj.stalled(*e) for e in reversed(events)]
        assert fwd == rev[::-1]          # order-independent decisions
        ids = [("a", i) for i in range(16)]
        assert inj.corrupt_chunks(ids) == inj.corrupt_chunks(ids[::-1])[::-1]
        assert inj.lost_shards(3, 8) == inj.lost_shards(3, 8)

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="probability"):
            FaultSpec(stall_rate=1.5)
        with pytest.raises(ValueError, match="stall_factor"):
            FaultSpec(stall_factor=0.5)

    def test_rates_zero_is_silent(self):
        inj = FaultInjector(FaultSpec(seed=1))
        assert not inj.stalled(1, ("a", 0), 0)
        assert inj.lost_shards(1, 8) == ()
        assert inj.corrupt_chunks([("a", 0)]) == []


class TestRetryPolicy:
    def test_backoff_caps(self):
        p = RetryPolicy(timeout_s=1.0, backoff_s=0.1, backoff_cap_s=0.3,
                        growth=2.0, max_retries=5)
        assert [p.backoff(k) for k in range(4)] == [0.1, 0.2, 0.3, 0.3]
        # 5 timeouts + backoffs 0.1+0.2+0.3+0.3+0.3
        assert p.worst_case_extra_s() == pytest.approx(5 * 1.0 + 1.2)

    def test_validation(self):
        with pytest.raises(ValueError, match="timeout_s"):
            RetryPolicy(timeout_s=0.0)
        with pytest.raises(ValueError, match="max_retries"):
            RetryPolicy(timeout_s=1.0, max_retries=-1)


class TestChecksums:
    def test_seal_and_verify_roundtrip(self, table):
        et = EncodedTable.from_table(table, chunk_rows=2048)
        for col in et.columns.values():
            for ch in col.chunks:
                assert ch.verify()

    def test_flip_one_bit_detected(self, table):
        et = EncodedTable.from_table(table, chunk_rows=2048)
        inj = FaultInjector(FaultSpec(seed=4))
        ch = et.columns["a"].chunks[0]
        assert inj.flip_bit(ch, "a", 0)
        assert not ch.verify()

    def test_guard_repairs_from_oracle(self, table, query):
        et = EncodedTable.from_table(table, chunk_rows=2048)
        oracle = execute_encoded(query.plan(), query.aggregates,
                                 EncodedTable.from_table(table,
                                                         chunk_rows=2048))
        guard = ChunkGuard(et)
        chaos = ChaosHarness(FaultSpec(seed=4, corrupt_rate=0.4),
                             guard=guard)
        corrupted = chaos.inject_corruption()
        assert corrupted                  # seed chosen to hit something
        got = execute_encoded(query.plan(), query.aggregates, et,
                              guard=guard)
        assert got == oracle              # bit-exact after repair
        assert guard.quarantined and set(guard.repaired) == \
            set(guard.quarantined)
        assert guard.repair_logical_bytes_total > 0
        # repaired chunks verify again: a second scan is clean
        n_repaired = len(guard.repaired)
        assert execute_encoded(query.plan(), query.aggregates, et,
                               guard=guard) == oracle
        assert len(guard.repaired) == n_repaired

    def test_no_repair_raises_typed(self, table, query):
        et = EncodedTable.from_table(table, chunk_rows=2048)
        guard = ChunkGuard(et, repair=False)
        chaos = ChaosHarness(FaultSpec(seed=4, corrupt_rate=0.4),
                             guard=guard, recover=False)
        assert chaos.inject_corruption()
        with pytest.raises(ChunkCorruptionError, match="checksum"):
            execute_encoded(query.plan(), query.aggregates, et,
                            guard=guard)


class TestEngineUnderChaos:
    def test_bit_exact_and_deterministic_under_stalls(self, table, query):
        def once():
            eng, clock = make_engine(
                table, FaultSpec(seed=7, stall_rate=0.4),
                retry=RetryPolicy(timeout_s=1e-9, backoff_s=1e-8,
                                  max_retries=2))
            return run_n(eng, clock, query, 10), eng.summary()
        oracle_eng = QueryEngine(table, clock=VirtualClock(),
                                 tiered=PlacementEngine.for_table(
                                     table, paper_tiers(table.nbytes),
                                     Policy.CACHE, chunk_rows=2048))
        oracle_eng.submit(query, deadline=math.inf)
        want = oracle_eng.run()[0].aggregates
        r1, s1 = once()
        r2, s2 = once()
        assert all(r.aggregates == want for r in r1)
        assert s1["resilience"] == s2["resilience"]
        assert [r.latency_s for r in r1] == [r.latency_s for r in r2]
        assert s1["resilience"]["stalls"] > 0
        assert s1["resilience"]["retries"] > 0

    def test_fault_free_chaos_equals_plain_tiered(self, table, query):
        """stall_rate=0 chaos must charge byte-for-byte, second-for-second
        what the plain tiered path charges."""
        eng_c, clk_c = make_engine(table, FaultSpec(seed=1))
        clk_p = VirtualClock()
        eng_p = QueryEngine(table, clock=clk_p,
                            tiered=PlacementEngine.for_table(
                                table, paper_tiers(table.nbytes // 2),
                                Policy.CACHE, chunk_rows=2048))
        rc = run_n(eng_c, clk_c, query, 5)
        rp = run_n(eng_p, clk_p, query, 5)
        for a, b in zip(rc, rp):
            assert a.aggregates == b.aggregates
            assert a.latency_s == b.latency_s
            assert a.tier == b.tier
        assert eng_c.summary()["energy"]["recovery_j"] == 0
        assert eng_c.summary()["tier"]["recovery_bytes"] == 0

    def test_retry_bytes_charged_exactly_once(self, table, query):
        """Ledger invariant: total meter bytes == nominal access bytes +
        one recovery line per query; retries never double-charge."""
        eng, clock = make_engine(
            table, FaultSpec(seed=7, stall_rate=0.5),
            retry=RetryPolicy(timeout_s=1e-9, max_retries=2))
        run_n(eng, clock, query, 8)
        meter = eng.tiered.meter
        by_kind = {}
        for c in meter.charges:
            by_kind.setdefault(c.kind, []).append(c)
        assert set(by_kind) == {"query", "recovery"}
        # at most one recovery line per qid
        qids = [c.qid for c in by_kind["recovery"]]
        assert len(qids) == len(set(qids))
        total_bytes = sum(c.fast_bytes + c.capacity_bytes
                          for c in meter.charges)
        assert total_bytes == (eng.tiered.fast_bytes_total
                               + eng.tiered.capacity_bytes_total)
        assert eng.tiered.recovery_bytes_total == sum(
            c.fast_bytes + c.capacity_bytes for c in by_kind["recovery"])

    def test_no_recovery_stalls_ride_to_completion(self, table, query):
        eng_r, clk_r = make_engine(
            table, FaultSpec(seed=7, stall_rate=0.4, stall_factor=64.0),
            retry=RetryPolicy(timeout_s=1e-9, max_retries=1))
        eng_n, clk_n = make_engine(
            table, FaultSpec(seed=7, stall_rate=0.4, stall_factor=64.0),
            recover=False)
        lat_r = sum(r.latency_s for r in run_n(eng_r, clk_r, query, 10))
        lat_n = sum(r.latency_s for r in run_n(eng_n, clk_n, query, 10))
        assert lat_r < lat_n          # abandoning beats riding a 64x stall
        assert eng_n.summary()["resilience"]["retries"] == 0

    def test_admission_rejects_inflated_estimate(self, table, query):
        """A fault rate that inflates the service estimate past the
        deadline rejects at submit — not a silent late miss."""
        spec = FaultSpec(seed=7, stall_rate=0.5)
        retry = RetryPolicy(timeout_s=5e-4, backoff_s=1e-4, max_retries=3)
        eng, clock = make_engine(table, spec, retry=retry)
        base = eng._est_service_s(
            type("P", (), {"bytes_scanned": eng.bytes_scanned(query),
                           "chunks": eng.chunk_accesses(query)})())
        assert eng.submit(query, deadline=clock() + base * 0.5) is None
        assert eng.rejected
        assert eng.submit(query, deadline=clock() + base * 2.0) is not None

    def test_breaker_demotes_and_recovers(self, table, query):
        breaker = CircuitBreaker(fail_threshold=2, cooldown_s=1e-3)
        eng, clock = make_engine(
            table, FaultSpec(seed=3, stall_rate=0.9, stall_factor=64.0),
            retry=RetryPolicy(timeout_s=1e-9, max_retries=1),
            breaker=breaker)
        run_n(eng, clock, query, 10)
        s = eng.summary()["resilience"]
        assert s["breaker"]["opens"] >= 1
        # while open, accesses are charged at the capacity tier
        assert eng.tiered.stats()["demoted"] in (True, False)  # well-formed
        # MEMCACHE ghost accounting survives demotion: placement state
        # keeps evolving even when charging is forced to capacity
        eng2, clock2 = make_engine(
            table, FaultSpec(seed=3, stall_rate=0.9, stall_factor=64.0),
            retry=RetryPolicy(timeout_s=1e-9, max_retries=1),
            breaker=CircuitBreaker(fail_threshold=1, cooldown_s=1e9),
            policy=Policy.MEMCACHE)
        run_n(eng2, clock2, query, 6)
        assert eng2.tiered.demoted
        assert eng2.tiered.freq.sum() > 0     # counters still advanced

    def test_degraded_reports_count_as_missed(self, table, query):
        mesh = make_mesh((1,), ("data",))
        st = ShardedTable.shard(table, mesh)
        eng, clock = make_engine(st, FaultSpec(seed=2, shard_loss_rate=0.9),
                                 recover=False)
        # the injector exempts 1-shard meshes (no failover target), so
        # force dropouts to exercise the engine's typed-degraded plumbing
        eng.chaos.injector.lost_shards = \
            lambda qid, n: (0,) if qid % 2 == 0 else ()
        results = run_n(eng, clock, query, 6)
        degraded = [r for r in results if r.degraded]
        assert degraded                      # seed chosen to lose shards
        for r in degraded:
            assert r.aggregates == {} and r.count == 0
            assert not r.met and r.error
        s = eng.summary()
        assert s["degraded"] == len(degraded)
        assert s["sla_attainment"] == (len(results) - len(degraded)) \
            / len(results)


class TestDegradedIdentities:
    """Empty/zero-row and all-shards-lost on every path: the canonical
    aggregate identity or a typed error — never a partial sum."""

    @pytest.mark.parametrize("mode", ("pallas", "xla_ref"))
    def test_empty_selection_identity_under_faults(self, table, mode):
        q = Query(Pred("a", "gt", 127), aggregates=("b",))   # matches none
        et = EncodedTable.from_table(table, chunk_rows=2048)
        guard = ChunkGuard(et)
        chaos = ChaosHarness(FaultSpec(seed=4, corrupt_rate=0.4),
                             guard=guard)
        chaos.inject_corruption()
        got = execute_encoded(q.plan(), q.aggregates, et, mode=mode,
                              guard=guard)
        assert got == {"b": identity_ints(SPEC["b"])}

    @pytest.mark.parametrize("mode", ("pallas", "xla_ref"))
    def test_zero_row_shard_recovery_is_identity(self, mode):
        """rows < rows_per_shard * shards: the tail shard holds only
        padding; recovering it must contribute the identity."""
        t = Table.synthetic("z", 7, {"a": 8, "b": 8}, seed=1)
        st = ShardedTable.shard(t, make_mesh((1,), ("data",)))
        q = Query(Pred("a", "ge", 0), aggregates=("b",))
        want = st.execute(q.plan(), q.aggregates, mode=mode)
        with pytest.raises(DegradedResultError, match="all 1 shards"):
            execute_degraded(st, q.plan(), q.aggregates, [0], mode=mode)
        got, _ = execute_degraded(st, q.plan(), q.aggregates, [],
                                  mode=mode)
        assert got == want

    def test_lost_shard_validation(self, table, query):
        st = ShardedTable.shard(table, make_mesh((1,), ("data",)))
        with pytest.raises(ValueError, match="outside"):
            execute_degraded(st, query.plan(), query.aggregates, [5])


class TestTornFiles:
    def test_torn_heartbeat_reads_as_missing(self, tmp_path):
        from repro.dist.fault_tolerance import Heartbeat
        clk = VirtualClock()
        hb = Heartbeat(tmp_path, "node.0", timeout_s=10, clock=clk)
        hb.beat(3)
        assert hb.fleet() == ["node.0"]
        inj = FaultInjector(FaultSpec(seed=6))
        assert inj.tear_file(tmp_path / "node.0.heartbeat")
        # torn file parses as garbage -> treated as missing, never raises
        assert hb.fleet() == []
        hb.beat(4)                           # a fresh beat heals it
        assert hb.fleet() == ["node.0"]

    def test_tear_is_seeded(self, tmp_path):
        p1, p2 = tmp_path / "x.json", tmp_path / "y"
        p2.mkdir()
        (p2 / "x.json").write_bytes(b"0123456789" * 20)
        p1.write_bytes(b"0123456789" * 20)
        FaultInjector(FaultSpec(seed=8)).tear_file(p1)
        FaultInjector(FaultSpec(seed=8)).tear_file(p2 / "x.json")
        assert p1.read_bytes() == (p2 / "x.json").read_bytes()


class TestPrefetchUnderChaos:
    """The async prefetch pipeline under injected faults: a stalled
    capacity->fast stream degrades its chunk to the synchronous read —
    never a wrong answer — its wasted bytes land on the query's single
    kind="recovery" line, and a seeded chaos+prefetch replay is
    bit-deterministic."""

    def _run(self, table, spec, prefetch_bytes, seed_queries=8):
        from repro.tier.prefetch import PrefetchPipeline

        clock = VirtualClock()
        pe = PlacementEngine.for_table(
            table, paper_tiers(max(1, int(table.nbytes * 0.4))),
            Policy.CACHE, chunk_rows=2048)
        pf = (PrefetchPipeline(pe, prefetch_bytes) if prefetch_bytes
              else None)
        chaos = ChaosHarness(spec,
                             retry=RetryPolicy(timeout_s=1e-6,
                                               max_retries=1))
        eng = QueryEngine(table, clock=clock, tiered=pe, chaos=chaos,
                          prefetch=pf)
        q = Query(Pred("a", "lt", 64), aggregates=("b",))
        out = run_n(eng, clock, q, seed_queries)
        return pe, eng, chaos, out

    def test_stalled_streams_never_wrong_and_charge_once(self, table):
        from collections import Counter

        spec = FaultSpec(seed=11, stall_rate=0.5)
        pe0, _, _, clean = self._run(table, FaultSpec(seed=11), 0)
        buf = max(1, int(table.nbytes * 0.25))
        pe1, eng1, chaos, faulted = self._run(table, spec, buf)
        for r0, r1 in zip(clean, faulted):
            assert r1.aggregates == r0.aggregates     # stall != wrong
        assert chaos.prefetch_stalls > 0              # faults actually hit
        recovery = [c for c in pe1.meter.charges if c.kind == "recovery"]
        assert all(n <= 1 for n in
                   Counter(c.qid for c in recovery).values())
        assert pe1.recovery_bytes_total == sum(
            c.fast_bytes + c.capacity_bytes for c in recovery)
        # stalled streams' waste reached the recovery ledger
        assert sum(c.capacity_bytes for c in recovery) > 0
        assert eng1.prefetch.stats()["stalled_chunks"] == \
            chaos.prefetch_stalls

    def test_chaos_prefetch_replay_is_deterministic(self, table):
        spec = FaultSpec(seed=5, stall_rate=0.3)
        buf = max(1, int(table.nbytes * 0.25))
        pe_a, eng_a, _, out_a = self._run(table, spec, buf)
        pe_b, eng_b, _, out_b = self._run(table, spec, buf)
        assert [r.aggregates for r in out_a] == \
            [r.aggregates for r in out_b]
        assert [r.latency_s for r in out_a] == \
            [r.latency_s for r in out_b]
        assert eng_a.prefetch.stats() == eng_b.prefetch.stats()
        assert pe_a.meter.summary() == pe_b.meter.summary()

    def test_prefetch_under_breaker_demotion_stages_nothing(self, table):
        from repro.tier.prefetch import PrefetchPipeline

        clock = VirtualClock()
        pe = PlacementEngine.for_table(
            table, paper_tiers(max(1, int(table.nbytes * 0.4))),
            Policy.CACHE, chunk_rows=2048)
        pf = PrefetchPipeline(pe, max(1, int(table.nbytes * 0.25)))
        # a breaker tripped open with an effectively infinite cooldown
        breaker = CircuitBreaker(fail_threshold=1, cooldown_s=1e9)
        breaker.record_fault(0.0)
        chaos = ChaosHarness(FaultSpec(seed=0), breaker=breaker)
        eng = QueryEngine(table, clock=clock, tiered=pe, chaos=chaos,
                          prefetch=pf)
        q = Query(Pred("a", "lt", 64), aggregates=("b",))
        run_n(eng, clock, q, 3)
        assert pe.demoted
        assert eng.prefetch.stats()["staged_chunks"] == 0
